"""Compare a fresh bench_fleet run against a committed baseline JSON.

    python scripts/bench_compare.py BASELINE.json FRESH.json [--tol 0.25]

Fails (exit 1) when the fresh run regresses by more than ``tol`` in any
policy×workload cell's loop throughput, in the batched fleet throughput,
or in any device-count cell of the mesh scaling curve (schema v3) whose
device count exists on both sides. WA columns are reported for context but
never gate: they are workload statistics, not performance. Cells present
on only one side are reported and skipped. A baseline taken on a different
host/backend (the ``host`` block) downgrades the run to report-only —
cross-host throughput diffs are apples to oranges; a baseline differing
ONLY in device count (same machine, different
``--xla_force_host_platform_device_count``) is likewise report-only, since
per-cell throughput scales with the mesh, but is called out as such —
the scaling curve is the place where device counts are compared
like-for-like.
"""

from __future__ import annotations

import json
import sys


def compare(base: dict, fresh: dict, tol: float) -> int:
    gate = True
    b_host = base.get("host")
    f_host = fresh.get("host")
    if b_host != f_host:
        strip = lambda h: {k: v for k, v in (h or {}).items()
                           if k != "devices"}
        if strip(b_host) == strip(f_host):
            print(
                "NOTE: device-count mismatch — baseline ran on "
                f"{(b_host or {}).get('devices')} device(s), this run on "
                f"{(f_host or {}).get('devices')} (same host otherwise). "
                "Per-cell throughput scales with the mesh, so reporting "
                "only, not gating; matching device counts in the scaling "
                "curve still diff like-for-like below."
            )
        else:
            print(
                "NOTE: baseline host metadata differs from this host — "
                "reporting only, not gating."
            )
            print(f"  baseline: {b_host}\n  fresh:    {f_host}")
        gate = False
    if base.get("mode") != fresh.get("mode"):
        print(
            f"NOTE: comparing mode={base.get('mode')!r} baseline against "
            f"mode={fresh.get('mode')!r} run — write counts differ, so the "
            "equilibrium mix differs; reporting only, not gating. Cells "
            "are compile-free per-write rates, so large drops still merit "
            "a look."
        )
        gate = False

    failures = []
    rows = []
    key = "steps_per_sec_loop"
    b_cells, f_cells = base.get("cells", {}), fresh.get("cells", {})
    min_sec = 0.25  # cells timed faster than this are scheduler noise
    one_sided = 0
    # per-cell keys this tool knows how to judge; anything else a cell
    # carries (new columns added by a later bench schema, e.g. wear
    # statistics) is REPORT-ONLY — an unknown key must never gate, and
    # must never make an older baseline incomparable
    gated_keys = {key, "sec", "wa_total_mean"}
    extra_keys = sorted(
        {k for c in (*b_cells.values(), *f_cells.values()) for k in c}
        - gated_keys
    )
    if extra_keys:
        print(
            "NOTE: cells carry keys this gate does not judge "
            f"(report-only): {', '.join(extra_keys)}"
        )
    for name in sorted(set(b_cells) | set(f_cells)):
        # a cell present on only one side (grid grew or shrank between
        # runs — e.g. new op-stream workloads) is REPORT-ONLY: there is
        # nothing to diff, and a changed grid must never fail the gate
        if name not in b_cells or name not in f_cells:
            side = "baseline" if name in b_cells else "fresh"
            rows.append((name, "—", "—", f"only in {side} run (not gated)"))
            one_sided += 1
            continue
        old = b_cells[name].get(key)
        new = f_cells[name].get(key)
        if old is None or new is None:
            rows.append((name, "—", "—", f"no {key} field (not gated)"))
            continue
        ratio = new / old if old else float("inf")
        flag = ""
        too_fast = min(
            b_cells[name].get("sec", min_sec), f_cells[name].get("sec", min_sec)
        ) < min_sec
        if ratio < 1.0 - tol:
            if too_fast:
                flag = f"ratio {ratio:.2f}x (<{min_sec}s sample, not gated)"
            else:
                flag = f"REGRESSION ({ratio:.2f}x)"
                failures.append(f"{name}: {old:.0f} → {new:.0f} steps/s")
        rows.append((name, f"{old:.0f}", f"{new:.0f}", flag))

    old_f, new_f = base.get("fleet_steps_per_sec"), fresh.get("fleet_steps_per_sec")
    if old_f and new_f:
        ratio = new_f / old_f
        flag = ""
        if ratio < 1.0 - tol:
            flag = f"REGRESSION ({ratio:.2f}x)"
            failures.append(f"fleet: {old_f:.0f} → {new_f:.0f} steps/s")
        rows.append(("<batched fleet>", f"{old_f:.0f}", f"{new_f:.0f}", flag))

    # mesh scaling curve (schema v3): per-device-count batched throughput.
    # Device counts are the cell keys, so a curve taken at a different
    # mesh width shows up as one-sided cells (report-only) instead of
    # poisoning the gate; matching counts gate like any other cell.
    b_sc, f_sc = base.get("scaling", {}), fresh.get("scaling", {})
    for d in sorted(set(b_sc) | set(f_sc), key=int):
        name = f"<scaling {d} dev>"
        if d not in b_sc or d not in f_sc:
            side = "baseline" if d in b_sc else "fresh"
            rows.append((name, "—", "—", f"only in {side} run (not gated)"))
            one_sided += 1
            continue
        old = b_sc[d].get("fleet_steps_per_sec")
        new = f_sc[d].get("fleet_steps_per_sec")
        if old is None or new is None:
            rows.append((name, "—", "—", "no throughput field (not gated)"))
            continue
        ratio = new / old if old else float("inf")
        flag = ""
        too_fast = min(
            b_sc[d].get("sec", min_sec), f_sc[d].get("sec", min_sec)
        ) < min_sec
        if ratio < 1.0 - tol:
            if too_fast:
                flag = f"ratio {ratio:.2f}x (<{min_sec}s sample, not gated)"
            else:
                flag = f"REGRESSION ({ratio:.2f}x)"
                failures.append(
                    f"scaling@{d}dev: {old:.0f} → {new:.0f} steps/s"
                )
        rows.append((name, f"{old:.0f}", f"{new:.0f}", flag))

    if not rows:
        print("no cells on either side — nothing to compare")
        return 0
    w = max(len(r[0]) for r in rows)
    print(f"{'cell'.ljust(w)}  {'baseline':>10}  {'fresh':>10}")
    for name, old, new, flag in rows:
        print(f"{name.ljust(w)}  {old:>10}  {new:>10}  {flag}")
    if one_sided:
        print(f"({one_sided} cell(s) present on only one side — "
              "reported, never gated)")

    if failures and gate:
        print(f"\nFAIL: >{tol:.0%} throughput regression:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"\nOK: no cell regressed by more than {tol:.0%}"
          + ("" if gate else " (not gating)"))
    return 0


def main(argv: list[str]) -> int:
    tol = 0.25
    if "--tol" in argv:
        i = argv.index("--tol")
        if i + 1 >= len(argv):
            print(__doc__)
            return 2
        tol = float(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    args = [a for a in argv if not a.startswith("--")]
    if len(args) != 2:
        print(__doc__)
        return 2
    with open(args[0]) as f:
        base = json.load(f)
    with open(args[1]) as f:
        fresh = json.load(f)
    return compare(base, fresh, tol)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
