"""Ad-hoc perf probe for the fleet write engine (not part of the suite).

Times simulate_fleet on the bench grid under knob variants. Usage:
    PYTHONPATH=src:. python scripts/perf_probe.py [writes] [variant ...]
"""

import sys

from repro.utils.hostdev import force_host_device_count

force_host_device_count()  # before jax init (see repro.utils.hostdev)

import time

from repro.core import fleet as F
from repro.core.fleet import simulate_fleet
from repro.core.ssd import Geometry

from benchmarks.bench_fleet import grid_specs

KEY_FULL = F._part_key

# NOTE: coarser partition keys were probe-able before the trace-time
# detector dispatch; now a sub-batch must be td-homogeneous, so every
# variant keeps the canonical key and varies only engine/trace knobs.
VARIANTS = {
    # name: (fast_path, trace_every, unroll)
    "ref-fullkey": (False, 1, 1),
    "split-fullkey": (True, 1, 1),
    "ref-fullkey-e500": (False, 500, 1),
    "split-fullkey-e500": (True, 500, 1),
    "ref-fullkey-e500-u2": (False, 500, 2),
    "split-fullkey-e500-u4": (True, 500, 4),
}


def main():
    writes = int(sys.argv[1]) if len(sys.argv) > 1 else 10_000
    names = sys.argv[2:] or list(VARIANTS)
    geom = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8)
    specs = grid_specs(geom, writes, seeds=(0, 1))
    b = len(specs)
    for name in names:
        fast, e, u = VARIANTS[name]
        kw = dict(sampler="jax", devices="auto", fast_path=fast,
                  trace_every=e, unroll=u)
        simulate_fleet(geom, specs, **kw)  # warm the jit cache
        dts = []
        for _ in range(3):
            t0 = time.time()
            simulate_fleet(geom, specs, **kw)
            dts.append(time.time() - t0)
        dt = min(dts)
        print(f"{name:26s} {b * writes / dt:10.0f} steps/s  "
              f"(best {dt:.2f}s of {['%.2f' % d for d in dts]})")


def per_policy(writes: int = 10_000):
    """Time each policy as its own 8-drive fleet (seeds 0-1, 4 workloads)."""
    import benchmarks.bench_fleet as B
    from repro.core.fleet import simulate_fleet as SF

    geom = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8)
    for pname, preset in B.POLICIES:
        specs = [s for s in grid_specs(geom, writes, seeds=(0, 1))
                 if s.name.startswith(pname + "/")]
        kw = dict(sampler="jax", devices="auto")
        SF(geom, specs, **kw)
        dts = []
        for _ in range(3):
            t0 = time.time()
            SF(geom, specs, **kw)
            dts.append(time.time() - t0)
        dt = min(dts)
        print(f"{pname:14s} {len(specs)} drives  "
              f"{len(specs) * writes / dt:10.0f} steps/s  ({dt:.2f}s)")


if __name__ == "__main__":
    if "--per-policy" in sys.argv:
        per_policy(int(sys.argv[1]) if sys.argv[1:2] and sys.argv[1].isdigit() else 10_000)
    else:
        main()
