#!/usr/bin/env bash
# Tier-1 test entry point.
#
#   scripts/run_tests.sh                # full suite
#   scripts/run_tests.sh --fast         # skip @pytest.mark.slow (multi-minute kernel sweeps)
#   scripts/run_tests.sh --bench-smoke  # reduced fleet benchmark → BENCH_fleet.json
#   scripts/run_tests.sh --bench-compare  # fresh smoke run diffed against the
#                                         # committed BENCH_fleet.json; fails on
#                                         # >25% throughput regression per cell
#   scripts/run_tests.sh <pytest args...>   # passed through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    # perf-trajectory lane: a small policy×workload grid through both the
    # batched fleet and the per-drive loop, emitting BENCH_fleet.json
    # (steps/sec per cell) for PR-over-PR comparison
    export PYTHONPATH=".:${PYTHONPATH}"
    exec python benchmarks/bench_fleet.py --smoke
fi

if [[ "${1:-}" == "--bench-compare" ]]; then
    # regression gate: run the smoke grid to a scratch file (the committed
    # baselines are left untouched) and diff per-cell throughput against
    # the committed SMOKE baseline (same mode ⇒ same write counts ⇒ the
    # 25% gate is meaningful); falls back to the default-mode headline
    # JSON (report-only: bench_compare does not gate across modes)
    export PYTHONPATH=".:${PYTHONPATH}"
    fresh="$(mktemp /tmp/bench_fleet.XXXXXX.json)"
    trap 'rm -f "$fresh"' EXIT
    python benchmarks/bench_fleet.py --smoke --out "$fresh"
    baseline=BENCH_fleet_smoke.json
    [[ -f "$baseline" ]] || baseline=BENCH_fleet.json
    exec python scripts/bench_compare.py "$baseline" "$fresh" --tol 0.25
fi

args=()
if [[ "${1:-}" == "--fast" ]]; then
    shift
    args+=(-m "not slow")
fi
exec python -m pytest -q "${args[@]}" "$@"
