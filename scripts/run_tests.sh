#!/usr/bin/env bash
# Tier-1 test entry point.
#
#   scripts/run_tests.sh                # full suite
#   scripts/run_tests.sh --fast         # skip @pytest.mark.slow (multi-minute kernel sweeps)
#   scripts/run_tests.sh --bench-smoke  # reduced fleet benchmark → BENCH_fleet.json
#   scripts/run_tests.sh <pytest args...>   # passed through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    # perf-trajectory lane: a small policy×workload grid through both the
    # batched fleet and the per-drive loop, emitting BENCH_fleet.json
    # (steps/sec per cell) for PR-over-PR comparison
    export PYTHONPATH=".:${PYTHONPATH}"
    exec python benchmarks/bench_fleet.py --smoke
fi

args=()
if [[ "${1:-}" == "--fast" ]]; then
    shift
    args+=(-m "not slow")
fi
exec python -m pytest -q "${args[@]}" "$@"
