#!/usr/bin/env bash
# Tier-1 test entry point.
#
#   scripts/run_tests.sh                # full suite
#   scripts/run_tests.sh --fast         # skip @pytest.mark.slow (multi-minute kernel
#                                       # sweeps) + the trim-smoke bench cell
#   scripts/run_tests.sh --trim-smoke   # TRIM/op-stream lane: the engine-equivalence
#                                       # + invariant tests marked `trim`, plus one
#                                       # op-stream bench cell (tpcc_churn)
#   scripts/run_tests.sh --wear-smoke   # wear/endurance lane: the scoring-equivalence
#                                       # + erase-accounting tests marked `wear`, plus
#                                       # one wear-leveling bench cell (wolf-wear)
#   scripts/run_tests.sh --fault-smoke  # fault/retirement lane: the fault-injection
#                                       # + bad-block tests marked `fault`, plus one
#                                       # finite-endurance bench cell (wolf-endurance)
#   scripts/run_tests.sh --mesh-smoke   # mesh executor lane: the multi-device
#                                       # shard_map equivalence tests marked `mesh`,
#                                       # plus one 2-device bench cell
#   scripts/run_tests.sh --bench-smoke  # reduced fleet benchmark → BENCH_fleet.json
#   scripts/run_tests.sh --bench-compare  # fresh smoke run diffed against the
#                                         # committed BENCH_fleet.json; fails on
#                                         # >25% throughput regression per cell
#   scripts/run_tests.sh <pytest args...>   # passed through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    # perf-trajectory lane: a small policy×workload grid through both the
    # batched fleet and the per-drive loop, emitting BENCH_fleet.json
    # (steps/sec per cell) for PR-over-PR comparison
    export PYTHONPATH=".:${PYTHONPATH}"
    exec python benchmarks/bench_fleet.py --smoke
fi

trim_bench_cell() {
    # one op-stream bench cell: the tpcc_churn column of the smoke grid,
    # written to a scratch file (committed baselines stay untouched)
    export PYTHONPATH=".:${PYTHONPATH}"
    local scratch status=0
    scratch="$(mktemp /tmp/bench_trim.XXXXXX.json)"
    python benchmarks/bench_fleet.py --smoke --only tpcc_churn \
        --out "$scratch" || status=$?
    rm -f "$scratch"
    return "$status"
}

if [[ "${1:-}" == "--trim-smoke" ]]; then
    # focused TRIM lane: every test marked `trim` (op-stream equivalence,
    # interleaved-trim invariants, the effective-OP acceptance sweep),
    # then one trim bench cell. The default --fast lane subsumes this:
    # the `trim` tests are not `slow`, and --fast appends the same cell.
    python -m pytest -q -m trim
    trim_bench_cell
    exit 0
fi

mesh_bench_cell() {
    # one 2-device mesh bench cell: a single policy column of the smoke
    # grid pinned to 2 devices (scratch output — baselines stay untouched);
    # exercises the shard_map executor end-to-end incl. ragged padding
    export PYTHONPATH=".:${PYTHONPATH}"
    local scratch status=0
    scratch="$(mktemp /tmp/bench_mesh.XXXXXX.json)"
    python benchmarks/bench_fleet.py --smoke --devices 2 --only wolf/uniform \
        --out "$scratch" || status=$?
    rm -f "$scratch"
    return "$status"
}

if [[ "${1:-}" == "--mesh-smoke" ]]; then
    # focused mesh lane: every test marked `mesh` (≥2-device shard_map
    # equivalence, ragged-sub-batch padding, compiled-step cache hits),
    # then one 2-device bench cell. The default --fast lane subsumes this:
    # the `mesh` tests are not `slow`, and --fast appends the same cell.
    python -m pytest -q -m mesh
    mesh_bench_cell
    exit 0
fi

if [[ "${1:-}" == "--wear-smoke" ]]; then
    # focused wear/endurance lane: every test marked `wear` (victim-scoring
    # equivalence oracles, erase-accounting conservation, wear analytics,
    # the mixed-weight fleet sweep), then one wear-leveling bench cell
    # (the wolf-wear/two_modal column, scratch output — baselines stay
    # untouched). --fast subsumes the tests; this lane is the quick loop
    # for iterating on the scoring layer.
    python -m pytest -q -m wear
    export PYTHONPATH=".:${PYTHONPATH}"
    scratch="$(mktemp /tmp/bench_wear.XXXXXX.json)"
    status=0
    python benchmarks/bench_fleet.py --smoke --only wolf-wear/two_modal \
        --out "$scratch" || status=$?
    rm -f "$scratch"
    exit "$status"
fi

fault_bench_cell() {
    # one finite-endurance bench cell: the wolf-endurance/uniform column of
    # the smoke grid (scratch output — baselines stay untouched); exercises
    # erase-fault injection, block retirement, and the degraded-lane
    # masking end-to-end, mixed into a sub-batch with fault-free drives
    export PYTHONPATH=".:${PYTHONPATH}"
    local scratch status=0
    scratch="$(mktemp /tmp/bench_fault.XXXXXX.json)"
    python benchmarks/bench_fleet.py --smoke --only wolf-endurance/uniform \
        --out "$scratch" || status=$?
    rm -f "$scratch"
    return "$status"
}

if [[ "${1:-}" == "--fault-smoke" ]]; then
    # focused fault/retirement lane: every test marked `fault` (zero-rate
    # bit-identity, retirement invariants, spare exhaustion + degraded
    # lanes, the shrunken-OP model acceptance), then one finite-endurance
    # bench cell. The default --fast lane subsumes this: the `fault` tests
    # are not `slow`, and --fast appends the same cell.
    python -m pytest -q -m fault
    fault_bench_cell
    exit 0
fi

if [[ "${1:-}" == "--bench-compare" ]]; then
    # regression gate: run the smoke grid to a scratch file (the committed
    # baselines are left untouched) and diff per-cell throughput against
    # the committed SMOKE baseline (same mode ⇒ same write counts ⇒ the
    # 25% gate is meaningful); falls back to the default-mode headline
    # JSON (report-only: bench_compare does not gate across modes)
    export PYTHONPATH=".:${PYTHONPATH}"
    fresh="$(mktemp /tmp/bench_fleet.XXXXXX.json)"
    trap 'rm -f "$fresh"' EXIT
    python benchmarks/bench_fleet.py --smoke --out "$fresh"
    baseline=BENCH_fleet_smoke.json
    [[ -f "$baseline" ]] || baseline=BENCH_fleet.json
    exec python scripts/bench_compare.py "$baseline" "$fresh" --tol 0.25
fi

if [[ "${1:-}" == "--fast" ]]; then
    shift
    # the trim-smoke and mesh-smoke tests ride along here (-m "not slow"
    # includes every `trim`- and `mesh`-marked test); the lanes' bench
    # cells run after the suite
    python -m pytest -q -m "not slow" "$@"
    trim_bench_cell
    mesh_bench_cell
    fault_bench_cell
    exit 0
fi
exec python -m pytest -q "$@"
