#!/usr/bin/env bash
# Tier-1 test entry point.
#
#   scripts/run_tests.sh          # full suite
#   scripts/run_tests.sh --fast   # skip @pytest.mark.slow (multi-minute kernel sweeps)
#   scripts/run_tests.sh <pytest args...>   # passed through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

args=()
if [[ "${1:-}" == "--fast" ]]; then
    shift
    args+=(-m "not slow")
fi
exec python -m pytest -q "${args[@]}" "$@"
