"""Paper Fig. 2: greedy vs LRU victim selection after movement-operation
bursts (double frequency swap at p=2%/98%)."""

from __future__ import annotations

import dataclasses

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.ssd import Geometry

from benchmarks.common import report, table


def run(full: bool = False) -> dict:
    geom = Geometry()
    writes = 60_000 if not full else 400_000
    ph1, ph2 = W.swap_phases(geom.lba_pages, writes, p=(0.02, 0.98))
    phases = [ph1, ph2, dataclasses.replace(ph1, n_writes=writes)]
    rows = []
    for name, mcfg in (("greedy", M.wolf()), ("lru", M.wolf_lru())):
        res = M.simulate(geom, mcfg, phases, seed=7)
        third = len(res.mig) // 3
        final_phase_mig = float(res.mig[-1] - res.mig[2 * third])
        rows.append({
            "policy": name,
            "migrations_after_2nd_swap": int(final_phase_mig),
            "wa_total": round(res.wa_total, 3),
        })
        print(rows[-1])
    pct = (
        (rows[1]["migrations_after_2nd_swap"] - rows[0]["migrations_after_2nd_swap"])
        / max(rows[0]["migrations_after_2nd_swap"], 1)
        * 100
    )
    out = {"figure": "2", "rows": rows, "lru_extra_migrations_pct": round(pct, 1)}
    report("greedy_lru", out)
    print(table(rows, list(rows[0].keys())))
    print(f"LRU migrates {pct:.1f}% more after the swap (paper: ~15%)")
    return out


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
