"""Paper Fig. 8: pairwise frequency swaps among 5 exponential groups —
migration difference (FDP − Wolf) normalized by PBA, per pair."""

from __future__ import annotations

import numpy as np

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.ssd import Geometry

from benchmarks.common import report, table


def run(full: bool = False) -> dict:
    geom = Geometry()
    writes = 80_000 if not full else 400_000
    base = W.exponential_groups(geom.lba_pages, writes)
    pairs = (
        [(0, 4), (0, 2), (1, 4), (2, 4), (3, 4)]
        if not full
        else [(i, j) for i in range(5) for j in range(i + 1, 5)]
    )
    rows = []
    for (i, j) in pairs:
        swapped = W.pairwise_swap(base, i, j, writes)
        extra = {}
        for name, mcfg in (("wolf", M.wolf()), ("fdp", M.fdp())):
            s = M.simulate(geom, mcfg, [base, swapped], seed=6)
            b = M.simulate(geom, mcfg, [base, base], seed=6)
            extra[name] = float(s.mig[-1] - b.mig[-1]) / geom.pba_pages
        rows.append({
            "pair": f"{i}<->{j}",
            "freq_gap": round(abs(base.probs[j] - base.probs[i]), 3),
            "wolf_extra/PBA": round(extra["wolf"], 3),
            "fdp_extra/PBA": round(extra["fdp"], 3),
            "fdp_minus_wolf": round(extra["fdp"] - extra["wolf"], 3),
        })
        print(rows[-1])
    out = {"figure": "8", "rows": rows}
    report("swap_matrix", out)
    print(table(rows, list(rows[0].keys())))
    return out


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
