"""Paper Figs. 3-5: closed-form OP allocation (eq. 8) vs hill-climbed
optimum across the workload-configuration space."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (
    allocate_by_frequency,
    allocate_by_size,
    allocate_closed_form,
    optimal_allocation,
    total_wa,
)

from benchmarks.common import report, table


def _wa(s, p, op):
    return float(total_wa(jnp.asarray(s), jnp.asarray(p), jnp.asarray(op)))


def sweep(n_groups: int, q: int, lba_pba: float, n_configs: int, rng):
    lba = 100_000.0
    op_total = lba * (1.0 / lba_pba - 1.0)
    errs, errs_size, errs_freq = [], [], []
    for _ in range(n_configs):
        s = rng.multinomial(q - n_groups, np.ones(n_groups) / n_groups) + 1
        p = rng.multinomial(q - n_groups, np.ones(n_groups) / n_groups) + 1
        s = s / q * lba
        p = p / q
        opt = optimal_allocation(jnp.asarray(s), jnp.asarray(p), jnp.asarray(op_total))
        wa_opt = _wa(s, p, opt)
        for policy, bucket in (
            (allocate_closed_form(jnp.asarray(s), jnp.asarray(p), op_total, cold_rule=False), errs),
            (allocate_by_size(jnp.asarray(s), op_total), errs_size),
            (allocate_by_frequency(jnp.asarray(p), op_total), errs_freq),
        ):
            bucket.append((_wa(s, p, policy) - wa_opt) / wa_opt * 100)
    return errs, errs_size, errs_freq


def run(full: bool = False) -> dict:
    rng = np.random.default_rng(0)
    n_configs = 10 if not full else 60
    rows = []
    for q in (10, 20):
        for n_groups in (2, 3, 5, 7, 9) if full else (2, 3, 5):
            errs, e_size, e_freq = sweep(n_groups, q, 0.7, n_configs, rng)
            rows.append({
                "Q": q, "groups": n_groups,
                "closed_avg_%off": round(float(np.mean(errs)), 3),
                "closed_max_%off": round(float(np.max(errs)), 3),
                "size_only_avg": round(float(np.mean(e_size)), 2),
                "freq_only_avg": round(float(np.mean(e_freq)), 2),
            })
            print(rows[-1])
    # Fig. 5: across over-provisioning levels (groups fixed at 5)
    for r in (0.6, 0.7, 0.8, 0.9):
        errs, _, _ = sweep(5, 10, r, n_configs, rng)
        rows.append({
            "Q": 10, "groups": 5, "lba_pba": r,
            "closed_avg_%off": round(float(np.mean(errs)), 3),
            "closed_max_%off": round(float(np.max(errs)), 3),
        })
        print(rows[-1])
    out = {"figure": "3-5", "rows": rows}
    report("allocation", out)
    return out


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
