"""Paper Fig. 1: equilibrium δ / WA vs LBA/PBA — analytical model vs
simulation (LRU matches eq. 3; greedy is the known slight improvement)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.analytics import delta_from_op_ratio, wa_from_op_ratio
from repro.core.ssd import Geometry

from benchmarks.common import report, table


def run(full: bool = False) -> dict:
    ratios = (0.6, 0.7, 0.8, 0.9) if not full else tuple(np.arange(0.55, 0.95, 0.05))
    writes = 150_000 if not full else 600_000
    geom0 = Geometry()
    rows = []
    for r in ratios:
        geom = dataclasses.replace(geom0, lba_pba=float(r))
        s = geom.lba_pages
        op_eff = geom.pba_pages - 3 * geom.pages_per_block - s
        r_eff = s / (s + op_eff)
        wa_model = float(wa_from_op_ratio(jnp.asarray(r_eff)))
        delta_model = float(delta_from_op_ratio(jnp.asarray(r_eff)))
        row = {
            "lba_pba": round(float(r), 3),
            "delta_eq3": round(delta_model, 4),
            "wa_eq3": round(wa_model, 3),
        }
        for policy in ("lru", "greedy"):
            mcfg = dataclasses.replace(M.single_group(), gc_policy=policy)
            res = M.simulate(geom, mcfg, [W.uniform(s, writes)], seed=1)
            wa = float(res.wa_curve(10_000)[-5:].mean())
            row[f"wa_{policy}"] = round(wa, 3)
            row[f"{policy}_vs_model"] = round(wa / wa_model, 3)
        rows.append(row)
        print(row)
    out = {"figure": "1", "rows": rows}
    report("equilibrium", out)
    print(table(rows, list(rows[0].keys())))
    return out


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
