"""Paper Figs. 9-10: TPC-C_init-shaped workload — Wolf (dynamic groups,
closed form, measured frequencies) vs FDP-style fixed group definition vs
the single-group baseline (grey line)."""

from __future__ import annotations

import numpy as np

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.ssd import Geometry

from benchmarks.common import report, table


def run(full: bool = False) -> dict:
    geom = Geometry()
    writes = 200_000 if not full else 1_500_000
    phase = W.tpcc_like(geom.lba_pages, writes)
    contenders = (
        ("wolf-dynamic", M.wolf_dynamic()),        # blue line
        ("wolf-oracle-groups", M.wolf()),          # red-ish: flexible + measured
        ("fdp-fixed-defn", M.fdp()),               # green line
        ("single-group", M.single_group()),        # grey line
    )
    rows, curves = [], {}
    for name, mcfg in contenders:
        res = M.simulate(geom, mcfg, [phase], seed=8)
        curve = res.wa_curve(window=writes // 25)
        curves[name] = [round(float(x), 3) for x in curve]
        n_groups = int(np.asarray(res.state["grp_active"]).sum())
        rows.append({
            "manager": name,
            "wa_equilibrium": round(float(curve[-5:].mean()), 3),
            "wa_total": round(res.wa_total, 3),
            "groups_final": n_groups,
        })
        print(rows[-1])
    base = rows[2]["wa_equilibrium"]  # fdp fixed definition
    best = rows[0]["wa_equilibrium"]
    out = {
        "figure": "9-10",
        "rows": rows,
        "curves": curves,
        "wolf_vs_fixed_defn_improvement_pct": round((base - best) / base * 100, 1),
    }
    report("tpcc", out)
    print(table(rows, list(rows[0].keys())))
    print(
        f"Wolf vs fixed-definition improvement: "
        f"{out['wolf_vs_fixed_defn_improvement_pct']}% (paper: ~22%)"
    )
    return out


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
