"""Paper Figs. 6-7: WA over time across a frequency swap — Wolf vs FDP.
Headline: extra migrations vs no-swap, normalized by PBA (paper: 0.7% vs
152.1%)."""

from __future__ import annotations

import numpy as np

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.ssd import Geometry

from benchmarks.common import report, table


def run(full: bool = False) -> dict:
    geom = Geometry() if not full else Geometry(
        n_luns=8, blocks_per_lun=256, pages_per_block=32
    )
    writes = 150_000 if not full else 1_000_000
    ph1, ph2 = W.swap_phases(geom.lba_pages, writes, p=(0.1, 0.9))
    rows, curves = [], {}
    for name, mcfg in (("wolf", M.wolf()), ("fdp", M.fdp())):
        swap = M.simulate(geom, mcfg, [ph1, ph2], seed=3)
        noswap = M.simulate(geom, mcfg, [ph1, ph1], seed=3)
        extra = float(swap.mig[-1] - noswap.mig[-1]) / geom.pba_pages
        curve = swap.wa_curve(window=writes // 30)
        curves[name] = [round(float(x), 3) for x in curve]
        half = len(curve) // 2
        rows.append({
            "manager": name,
            "extra_migrations/PBA": round(extra, 4),
            "wa_before_swap": round(float(curve[half - 3:half].mean()), 3),
            "wa_peak_after": round(float(curve[half:half + 6].max()), 3),
            "wa_final": round(float(curve[-3:].mean()), 3),
            "wa_total": round(swap.wa_total, 3),
        })
        print(rows[-1])
    ratio = rows[1]["extra_migrations/PBA"] / max(rows[0]["extra_migrations/PBA"], 1e-4)
    out = {"figure": "6-7", "rows": rows, "curves": curves,
           "fdp_vs_wolf_extra_ratio": round(ratio, 1)}
    report("freq_swap", out)
    print(table(rows, list(rows[0].keys())))
    print(f"FDP pays {ratio:.0f}x more extra migrations than Wolf (paper: ~217x)")
    return out


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
