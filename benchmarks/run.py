"""Benchmark driver: one benchmark per paper figure + the TPU adaptation.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import time

BENCHMARKS = (
    ("equilibrium", "Fig 1  — eq.3 vs simulation"),
    ("allocation", "Fig 3-5 — closed-form vs optimal OP allocation"),
    ("greedy_lru", "Fig 2  — greedy vs LRU after movement ops"),
    ("freq_swap", "Fig 6-7 — Wolf vs FDP across a frequency swap"),
    ("swap_matrix", "Fig 8  — pairwise swap matrix"),
    ("tpcc", "Fig 9-10 — TPC-C-like realistic workload"),
    ("wolf_kv", "TPU adaptation — Wolf-KV serving WA"),
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale runs")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    results = {}
    for name, desc in BENCHMARKS:
        if args.only and args.only != name:
            continue
        print(f"\n=== {name}: {desc} ===")
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        results[name] = mod.run(full=args.full)
        print(f"[{name}] {time.time() - t0:.1f}s")
    print("\nAll benchmark reports under reports/benchmarks/.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
