"""Shared benchmark helpers: reporting + reduced/full scale presets."""

from __future__ import annotations

import json
import pathlib
import time

REPORT_DIR = pathlib.Path("reports/benchmarks")


def report(name: str, payload: dict):
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    path = REPORT_DIR / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=float))
    return path


def table(rows: list[dict], columns: list[str]) -> str:
    widths = {
        c: max(len(c), *(len(f"{r.get(c, '')}") for r in rows)) for c in columns
    }
    lines = ["  ".join(c.ljust(widths[c]) for c in columns)]
    lines.append("  ".join("-" * widths[c] for c in columns))
    for r in rows:
        lines.append("  ".join(f"{r.get(c, '')}".ljust(widths[c]) for c in columns))
    return "\n".join(lines)


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
