"""TPU adaptation benchmark: Wolf-KV paged-cache write-amplification under a
churn-class swap — adaptive (Wolf) vs static split (FDP-analogue).

The serving counterpart of Figs. 6-7: two sequence classes swap their
eviction behaviour mid-run; WA in the post-swap phase is the score."""

from __future__ import annotations

import numpy as np

from repro.kvcache.manager import WolfKVManager

from benchmarks.common import report, table


def _run(adaptive: bool, *, n_blocks=128, page=8, steps=4000, seed=2) -> dict:
    mgr = WolfKVManager(n_blocks, page, 2, adaptive=adaptive, interval=256)
    rng = np.random.default_rng(seed)
    mgr.add_sequence(0, 0)
    mgr.add_sequence(1, 1)
    for _ in range(96):
        mgr.append_token(0)
        mgr.append_token(1)

    if not adaptive:  # freeze a split fitted to phase 1 (class B hot)
        mgr.groups[0].alloc_blocks = 20
        mgr.groups[1].alloc_blocks = 90

    def churn(sid, hot):
        mgr.append_token(sid)
        if hot:
            seq = mgr.seqs[sid]
            alive = np.flatnonzero(seq.valid[: seq.cache_len])
            mgr.evict_token(sid, int(rng.choice(alive[:-1])))

    for _ in range(steps):  # phase 1: B hot
        churn(1, True)
        if rng.random() < 0.1:
            churn(0, False)
    phase1_wa = mgr.write_amplification
    mark = mgr.mark()
    for _ in range(steps):  # phase 2 (swap): A hot
        churn(0, True)
    mgr.check_invariants()
    return {
        "wa_phase1": round(phase1_wa, 3),
        "wa_phase2": round(mgr.wa_since(mark), 3),
        "copied": mgr.copied,
        "appended": mgr.appended,
    }


def run(full: bool = False) -> dict:
    steps = 4000 if not full else 20_000
    rows = []
    for name, adaptive in (("wolf-kv (adaptive)", True), ("static split", False)):
        r = _run(adaptive, steps=steps)
        rows.append({"manager": name, **r})
        print(rows[-1])
    imp = (rows[1]["wa_phase2"] - rows[0]["wa_phase2"]) / rows[1]["wa_phase2"] * 100
    out = {"rows": rows, "post_swap_wa_improvement_pct": round(imp, 1)}
    report("wolf_kv", out)
    print(table(rows, list(rows[0].keys())))
    print(f"Wolf-KV post-swap WA improvement vs static: {imp:.1f}%")
    return out


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
