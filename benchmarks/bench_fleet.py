"""Fleet throughput: the shard_map drive-axis fleet over a policy × workload
grid vs a Python loop of per-drive ``managers.simulate`` on the same grid.

Reports drives/sec for both paths (post-warmup, i.e. compile excluded for
both), the speedup, the per-drive equilibrium WA curves of the grid — the
batched analogue of the paper's §6 policy comparisons — and a 1→N
device-count scaling curve for the mesh executor (the batched path at 1, 2
and every visible device; on CPU the devices are virtual cores, on an
accelerator they are chips — same code, same numbers expected to be
bit-identical, only wall-clock moves).

The speedup is hardware-dependent: XLA:CPU executes batched gather/scatter
serially per lane, so on CPU the vmap win comes from shard_map sharding
across cores (virtual host devices, set up below) and dispatch
amortization; on an accelerator backend the same code batches the lanes in
silicon. (The executor is ``jit(shard_map(vmap))`` over
``launch.mesh.drive_mesh`` — the old pmap path is gone; see
core/fleet_exec.py.)

Every run emits ``BENCH_fleet.json`` at the repo root (schema
``bench_fleet/v3``): steps/sec for the batched fleet, per policy × workload
cell (loop path), the ``scaling`` curve per device count, plus host/JAX
metadata (platform, python, jax version, backend, device count) so
PR-over-PR comparisons are pinned to a host AND a backend — the trajectory
is multi-backend from v3 on. ``--smoke`` runs a reduced grid for the CI
lane (``scripts/run_tests.sh --bench-smoke``); ``--out PATH`` redirects
the JSON (used by ``--bench-compare`` to diff a fresh run against the
committed baseline without clobbering it); ``--only SUBSTR`` restricts the
grid to matching cells (the ``--trim-smoke`` lane benches just the
``tpcc_churn`` op-stream cells that way); ``--devices D`` pins the fleet
to D devices (the ``--mesh-smoke`` lane benches one 2-device cell that
way). The scaling sweep runs only on full-grid, unpinned runs.
"""

from __future__ import annotations

import os

from repro.utils.hostdev import force_host_device_count

# must run before jax initializes: expose the cores as host devices so the
# fleet can shard_map its sub-batches (min 2 so the scaling curve always
# has a multi-device point, even on a 1-core container — virtual devices
# oversubscribe threads; structure stays, speedup needs real cores)
force_host_device_count(max(os.cpu_count() or 1, 2))
# the legacy XLA:CPU runtime dispatches the write-step's many tiny
# gather/scatter ops ~2.5× faster than the thunk runtime on this workload
# (measured: 40k → 99k fleet steps/s on the default grid); numerics are
# unchanged — it is the same compiled computation under a different
# executor. Override by putting the flag in XLA_FLAGS yourself.
if "--xla_cpu_use_thunk_runtime" not in os.environ["XLA_FLAGS"]:
    os.environ["XLA_FLAGS"] += " --xla_cpu_use_thunk_runtime=false"

import json
import pathlib
import platform
import sys

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.ssd import Geometry

from benchmarks.common import report, table, timer

POLICIES = (
    ("wolf", M.wolf),
    ("wolf-dynamic", M.wolf_dynamic),
    # wear-leveled weight point of the unified victim score (β=0.25):
    # benchmarked so the scoring layer's cost shows up in the trajectory
    # and the wear columns have a leveled row to compare against
    ("wolf-wear", M.wolf_wear),
    ("fdp", M.fdp),
    ("single", M.single_group),
    # finite-endurance row: wolf on an AGING drive — blocks retire once
    # their P-E count crosses the limit, shrinking the OP the allocator
    # divides. Shares a sub-batch with wolf/wolf-wear (faults are traced
    # data, not a partition dimension), so this row also keeps the mixed
    # faulty/fault-free compiled path on the benchmarked trajectory.
    ("wolf-endurance", M.wolf_endurance),
)


def grid_specs(geom: Geometry, writes: int, seeds=(0,),
               only: str | None = None) -> list[DriveSpec]:
    lba = geom.lba_pages
    # scale the P-E limit to the run length so every mode ages its drives
    # into visible retirement (mean P-E at this geometry ≈ writes·WA/(K·B))
    pe_limit = max(writes // 4000, 1)

    def preset_cfg(pname, preset):
        if pname == "wolf-endurance":
            return preset(endurance_pe_limit=pe_limit)
        return preset()
    workloads = (
        ("uniform", lambda: (W.uniform(lba, writes),)),
        ("two_modal", lambda: (W.two_modal(lba, writes),)),
        ("swap", lambda: tuple(W.swap_phases(lba, writes // 2))),
        ("tpcc", lambda: (W.tpcc_like(lba, writes),)),
        # op-stream cells: the TPC-C insert/update/delete churn (TRIMs
        # interleaved) — these exercise the WRITE/TRIM dispatch engine;
        # the pure-write cells above keep their historical streams (the
        # fleet partitions op-stream drives into their own sub-batch)
        ("tpcc_churn", lambda: (W.tpcc_churn(lba, writes),)),
    )
    specs = [
        DriveSpec(
            preset_cfg(pname, preset), wl(), seed=seed,
            name=f"{pname}/{wname}#{seed}"
        )
        for seed in seeds
        for pname, preset in POLICIES
        for wname, wl in workloads
    ]
    if only:
        specs = [s for s in specs if only in s.name]
        assert specs, f"--only {only!r} matched no grid cell"
    return specs


def run(full: bool = False, smoke: bool = False,
        out_path: str | None = None, only: str | None = None,
        devices: int | None = None) -> dict:
    # compile-once within this run comes from the in-process runner memo
    # (fleet_exec); the on-disk compilation cache is NOT enabled here —
    # set REPRO_JAX_CACHE_DIR to opt in (simulate_fleet wires it), but
    # see the hazard note on enable_persistent_compilation_cache first:
    # on jaxlib 0.4.37/XLA:CPU, serializing the Pallas-bearing step
    # executables corrupts the heap and kills the bench mid-grid
    geom = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8)
    writes = 60_000 if full else (4_000 if smoke else 20_000)
    seeds = (0,) if smoke else (0, 1)  # 5 policies × 5 workloads × seeds
    specs = grid_specs(geom, writes, seeds, only=only)

    # -- fleet path: warm the jit cache, then time steady-state ------------
    # trace stride: the grid's WA analysis samples windows of writes//10,
    # so a stride of writes//40 loses nothing while cutting the per-step
    # trace stores from the hot scan (engine default stays dense)
    trace_every = max(writes // 40, 1)
    fleet_kw = dict(
        sampler="jax", devices=devices if devices else "auto",
        trace_every=trace_every,
    )
    simulate_fleet(geom, specs, **fleet_kw)
    # best of 3: the whole-grid call is sub-10s post-refactor, so a single
    # sample is at the mercy of host scheduling noise
    fleet_sec = None
    for _ in range(3):
        with timer() as t_rep:
            fleet = simulate_fleet(geom, specs, **fleet_kw)
        fleet_sec = t_rep.dt if fleet_sec is None else min(fleet_sec, t_rep.dt)

    # -- loop path: same grid, per-drive managers.simulate, timed per drive
    # (per policy×workload cell steps/sec). Warm each DISTINCT jit
    # signature first — the compiled shape includes the scan length, the
    # drive's group count (from the first phase's group structure), AND
    # whether the op-stream engine is traced (trim-bearing phases), so the
    # warm key carries all three; warming at a reduced write count would
    # leave every timed cell paying XLA compilation (and cells would not
    # be comparable across modes).
    for s in {
        (s.mcfg.name,
         tuple((ph.n_writes, len(ph.sizes), ph.has_trim)
               for ph in s.phases)): s
        for s in specs
    }.values():
        M.simulate(geom, s.mcfg, list(s.phases), seed=0)
    loop_results, drive_secs = [], []
    with timer() as t_loop:
        for s in specs:
            with timer() as t_drive:
                loop_results.append(
                    M.simulate(geom, s.mcfg, list(s.phases), seed=s.seed)
                )
            drive_secs.append(t_drive.dt)

    b = len(specs)
    fleet_dps = b / fleet_sec
    loop_dps = b / t_loop.dt
    speedup = fleet_dps / loop_dps

    # -- device-count scaling curve (1 → 2 → N): the mesh executor's
    # multi-backend trajectory. Results are bit-identical per drive at
    # every point (tests/test_fleet_mesh.py), so only wall-clock moves; on
    # a CPU with virtual devices the curve is flat-to-worse (threads
    # oversubscribe cores) but the per-backend shape is exactly what the
    # trajectory tracks. Skipped on pinned-device or filtered runs (quick
    # CI cells).
    import jax

    scaling = {}
    if devices is None and only is None:
        n_host = len(jax.devices())
        for d in sorted({1, 2, n_host}):
            kw = dict(fleet_kw, devices=d)
            simulate_fleet(geom, specs, **kw)  # warm (compile excluded)
            d_sec = None
            for _ in range(2):
                with timer() as t_d:
                    simulate_fleet(geom, specs, **kw)
                d_sec = t_d.dt if d_sec is None else min(d_sec, t_d.dt)
            scaling[str(d)] = {
                "devices": d,
                "sec": round(d_sec, 3),
                "fleet_steps_per_sec": round(b * writes / d_sec, 1),
            }
        base_sps = scaling["1"]["fleet_steps_per_sec"]
        for cell in scaling.values():
            cell["speedup_vs_1dev"] = round(
                cell["fleet_steps_per_sec"] / base_sps, 3
            )

    window = max(writes // 10, 500)
    # endurance columns ride on the carried O(1) aggregates — no extra
    # simulation work, just a read-off per drive
    wear_var = fleet.wear_variance()
    wear_imb = fleet.wear_imbalance()
    # survival columns (retired capacity + degraded lanes): zeros for every
    # fault-free row, the aging story for the wolf-endurance row
    retired_frac = fleet.retired_fraction()
    status = fleet.drive_status()
    rows = []
    cells: dict[str, dict] = {}
    for i, s in enumerate(specs):
        cell = s.name.rsplit("#", 1)[0]  # "policy/workload"
        c = cells.setdefault(
            cell, {"sec": 0.0, "n": 0, "wa": [], "wvar": [], "wimb": [],
                   "rfrac": [], "degraded": 0}
        )
        c["sec"] += drive_secs[i]
        c["n"] += 1
        c["wa"].append(float(fleet.wa_total[i]))
        c["wvar"].append(float(wear_var[i]))
        c["wimb"].append(float(wear_imb[i]))
        c["rfrac"].append(float(retired_frac[i]))
        c["degraded"] += int(status[i] != 0)
        if s.seed != seeds[0]:
            continue
        curve = fleet.result(i).wa_curve(window)
        rows.append({
            "drive": s.name,
            "wa_total": round(float(fleet.wa_total[i]), 3),
            "wa_equilibrium": round(float(curve[-3:].mean()), 3),
            "loop_wa_total": round(loop_results[i].wa_total, 3),
            "wear_var": round(float(wear_var[i]), 2),
            "wear_imbalance": round(float(wear_imb[i]), 3),
            "retired_frac": round(float(retired_frac[i]), 4),
            "degraded": int(status[i] != 0),
        })
    print(table(rows, list(rows[0].keys())))
    summary = {
        "drives": b,
        "writes_per_drive": writes,
        "host_devices": len(jax.devices()),
        "fleet_devices": fleet.devices_used,
        "fleet_sec": round(fleet_sec, 3),
        "loop_sec": round(t_loop.dt, 3),
        "fleet_drives_per_sec": round(fleet_dps, 3),
        "loop_drives_per_sec": round(loop_dps, 3),
        "fleet_steps_per_sec": round(b * writes / fleet_sec, 1),
        "loop_steps_per_sec": round(b * writes / t_loop.dt, 1),
        "speedup": round(speedup, 2),
    }
    out = {
        "summary": summary,
        "rows": rows,
        "wa_curves": {
            s.name: [round(float(x), 3) for x in fleet.result(i).wa_curve(window)]
            for i, s in enumerate(specs) if s.seed == seeds[0]
        },
    }
    report("fleet", out)

    # machine-readable perf trajectory, tracked PR-over-PR; host/JAX
    # metadata pins WHERE the numbers were taken (host AND backend — the
    # scaling curve makes the trajectory multi-backend) so bench-compare
    # across hosts is recognizable as apples-to-oranges
    bench = {
        "schema": "bench_fleet/v3",
        "mode": "smoke" if smoke else ("full" if full else "default"),
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "python": sys.version.split()[0],
            "cpu_count": os.cpu_count(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "devices": len(jax.devices()),
        },
        "config": {
            "drives": b, "writes_per_drive": writes,
            "trace_every": trace_every,
            "geometry": {
                "n_luns": geom.n_luns, "blocks_per_lun": geom.blocks_per_lun,
                "pages_per_block": geom.pages_per_block,
                "lba_pba": geom.lba_pba,
            },
            "host_devices": len(jax.devices()),
            "fleet_devices": fleet.devices_used,
        },
        "fleet_steps_per_sec": summary["fleet_steps_per_sec"],
        "loop_steps_per_sec": summary["loop_steps_per_sec"],
        "speedup": summary["speedup"],
        # per-device-count batched-fleet throughput (empty on pinned or
        # --only runs); bench_compare diffs cells with matching counts
        "scaling": scaling,
        "cells": {
            name: {
                "steps_per_sec_loop": round(c["n"] * writes / c["sec"], 1),
                # measurement duration: bench_compare refuses to gate on
                # cells too fast to time reliably
                "sec": round(c["sec"], 4),
                "wa_total_mean": round(sum(c["wa"]) / c["n"], 4),
                # endurance context (never gated, like the WA column):
                # erase-count variance and max/mean P-E imbalance
                "wear_var_mean": round(sum(c["wvar"]) / c["n"], 4),
                "wear_imbalance_mean": round(sum(c["wimb"]) / c["n"], 4),
                # survival context (report-only, like the wear columns):
                # mean retired-capacity fraction + degraded-drive count
                "retired_frac_mean": round(sum(c["rfrac"]) / c["n"], 4),
                "degraded_count": c["degraded"],
            }
            for name, c in sorted(cells.items())
        },
    }
    bench_path = (
        pathlib.Path(out_path) if out_path
        else pathlib.Path(__file__).resolve().parent.parent / "BENCH_fleet.json"
    )
    bench_path.write_text(json.dumps(bench, indent=2))
    print(f"\nwrote {bench_path}")
    print(
        f"fleet: {b} drives × {writes} writes in {fleet_sec:.2f}s "
        f"({fleet_dps:.2f} drives/s, {summary['fleet_steps_per_sec']:.0f} steps/s) | "
        f"loop: {t_loop.dt:.2f}s ({loop_dps:.2f} drives/s) | "
        f"speedup ×{speedup:.1f}"
    )
    return out


if __name__ == "__main__":
    out = None
    if "--out" in sys.argv:
        out = sys.argv[sys.argv.index("--out") + 1]
    only = None
    if "--only" in sys.argv:  # cell filter, e.g. --only tpcc_churn
        only = sys.argv[sys.argv.index("--only") + 1]
    devices = None
    if "--devices" in sys.argv:  # pin the fleet's device count (mesh lane)
        devices = int(sys.argv[sys.argv.index("--devices") + 1])
    run(full="--full" in sys.argv, smoke="--smoke" in sys.argv,
        out_path=out, only=only, devices=devices)
