"""Mixture-of-Experts FFN (olmoe-1b-7b: 64e top-8; mixtral-8x22b: 8e top-2).

Two implementations, selected by ``MOE_IMPL``:

* ``capacity`` (default) — GShard-style dispatch/combine einsums with a
  per-group expert capacity ``C = tokens_per_group · top_k / E · cf``.
  Compute overhead vs ideal is only the capacity factor; tokens above
  capacity are dropped (their residual passes through). Group size bounds
  the dispatch tensor [G, t, E, C] to a few hundred MB at our shapes.
* ``dense`` — every expert processes every token, combined with (renormalized)
  top-k gates. E/k× overcompute, but exact (no drops): it is the test oracle
  for ``capacity`` and the deliberately naive §Perf baseline.

Expert weights are laid out [E, d, f]: the expert dim shards over ``model``
when divisible (EP), otherwise f shards over ``model`` (TP fallback) — the
auto-sharder (sharding/auto.py) resolves this per arch × mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, param_dtype
from repro.sharding.rules import logical_constraint

MOE_IMPL = "capacity"  # module switch; tests/benchmarks flip it explicitly


def tokens_per_group(cfg: ModelConfig, total_tokens: int) -> int:
    base = 256 if cfg.top_k > 4 else 1024
    return min(base, total_tokens)


def moe_init(rng, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = param_dtype(cfg)
    ks = jax.random.split(rng, 4)
    params = {
        "router": dense_init(ks[0], (d, e), 0, jnp.float32),
        "wi_gate": dense_init(ks[1], (e, d, f), 1, dt),
        "wi_up": dense_init(ks[2], (e, d, f), 1, dt),
        "wo": dense_init(ks[3], (e, f, d), 1, dt),
    }
    return params


def _router(params, x2d: jax.Array, cfg: ModelConfig):
    """x2d: [T, d] -> (gates [T, k] fp32, idx [T, k] int32)."""
    logits = x2d.astype(jnp.float32) @ params["router"]  # [T, E]
    top_vals, top_idx = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)  # renormalize over chosen
    return gates, top_idx


def _expert_ffn(params, xe: jax.Array, cfg: ModelConfig) -> jax.Array:
    """xe: [..., E, C, d] -> [..., E, C, d] through each expert's SwiGLU."""
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["wi_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["wi_up"])
    h = logical_constraint(h, "batch", "p_experts", None, "d_ff")
    return jnp.einsum("gecf,efd->gecd", h, params["wo"])


def moe_apply_capacity(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    t_total = b * s
    x2d = x.reshape(t_total, d)
    gates, idx = _router(params, x2d, cfg)  # [T,k]

    tpg = tokens_per_group(cfg, t_total)
    pad = (-t_total) % tpg
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
        gates = jnp.pad(gates, ((0, pad), (0, 0)))
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
    g = x2d.shape[0] // tpg
    e = cfg.n_experts
    cap = max(1, int(tpg * cfg.top_k / e * cfg.capacity_factor))

    xg = x2d.reshape(g, tpg, d)
    idx_g = idx.reshape(g, tpg, cfg.top_k)
    gate_g = gates.reshape(g, tpg, cfg.top_k).astype(x.dtype)

    # Position of each (token, choice) within its expert's capacity buffer.
    # Priority is token-major then choice-major (GShard convention).
    oh = jax.nn.one_hot(idx_g, e, dtype=jnp.int32)  # [g, t, k, E]
    oh_flat = oh.transpose(0, 2, 1, 3).reshape(g, cfg.top_k * tpg, e)
    # choice-major flatten gives choice 0 priority over choice 1 at same token
    pos_flat = jnp.cumsum(oh_flat, axis=1) - oh_flat  # [g, k*t, E]
    pos = (pos_flat * oh_flat).sum(-1).reshape(g, cfg.top_k, tpg).transpose(0, 2, 1)
    keep = pos < cap  # [g, t, k]

    # Dispatch/combine tensors, accumulated one choice at a time to avoid a
    # [g, t, k, E, C] intermediate.
    dispatch = jnp.zeros((g, tpg, e, cap), x.dtype)
    combine = jnp.zeros((g, tpg, e, cap), x.dtype)
    for j in range(cfg.top_k):
        sel = (
            jax.nn.one_hot(idx_g[:, :, j], e, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(pos[:, :, j], cap, dtype=x.dtype)[:, :, None, :]
        )
        sel = sel * keep[:, :, j, None, None].astype(x.dtype)
        dispatch = dispatch + sel
        combine = combine + sel * gate_g[:, :, j, None, None]

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    xe = logical_constraint(xe, "batch", "p_experts", None, "d_model")
    ye = _expert_ffn(params, xe, cfg)
    out = jnp.einsum("gtec,gecd->gtd", combine, ye)
    out = out.reshape(-1, d)[:t_total].reshape(b, s, d)
    return logical_constraint(out, "batch", "seq", "d_model")


def moe_apply_dense(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, idx = _router(params, x2d, cfg)
    e = cfg.n_experts
    full_gates = jnp.zeros((b * s, e), jnp.float32)
    full_gates = jax.vmap(lambda fg, i, g: fg.at[i].set(g))(full_gates, idx, gates)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", x2d, params["wi_gate"]))
    h = h * jnp.einsum("td,edf->etf", x2d, params["wi_up"])
    ye = jnp.einsum("etf,efd->etd", h, params["wo"])
    out = jnp.einsum("te,etd->td", full_gates.astype(x.dtype), ye)
    return out.reshape(b, s, d)


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if MOE_IMPL == "dense" or x.shape[1] == 1:
        # Decode (one token per sequence) always uses the exact dense path:
        # with B·k ≳ E every expert's weights stream from HBM anyway, so
        # decode is memory-bound and capacity-style drops would buy nothing
        # while making decode ≠ prefill numerics.
        return moe_apply_dense(params, x, cfg)
    return moe_apply_capacity(params, x, cfg)
