"""Shared model building blocks: norms, RoPE, MLPs, embeddings, init.

Functional style: ``*_init(rng, cfg) -> params dict`` and
``*_apply(params, x, ...) -> array``. Parameters live in plain nested dicts so
jax.tree_util, checkpointing, and pjit sharding all work untouched.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import logical_constraint

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(rng, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (maxtext-style 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return std * jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return jax.random.normal(rng, shape, dtype) * 0.02


def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dtype)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, d_head]; positions: [..., seq] (int)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # [d_head/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, d/2]
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense): SwiGLU or GELU
# ---------------------------------------------------------------------------

def mlp_init(rng, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    d_ff = d_ff or cfg.d_ff
    dt = param_dtype(cfg)
    ks = jax.random.split(rng, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wi_gate": dense_init(ks[0], (d, d_ff), 0, dt),
            "wi_up": dense_init(ks[1], (d, d_ff), 0, dt),
            "wo": dense_init(ks[2], (d_ff, d), 0, dt),
        }
    return {
        "wi": dense_init(ks[0], (d, d_ff), 0, dt),
        "wo": dense_init(ks[1], (d_ff, d), 0, dt),
    }


def mlp_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: [batch, seq, d_model] -> same."""
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["wi_gate"]) * (x @ params["wi_up"])
    else:
        h = jax.nn.gelu(x @ params["wi"], approximate=True)
    h = logical_constraint(h, "batch", "seq", "d_ff")
    out = h @ params["wo"]
    return logical_constraint(out, "batch", "seq", "d_model")


# ---------------------------------------------------------------------------
# Embedding / unembedding with chunked cross-entropy (never materializes the
# full [B, S, vocab] logits — required at vocab 100k × seq 4k scales).
# ---------------------------------------------------------------------------

def embedding_init(rng, cfg: ModelConfig) -> dict:
    dt = param_dtype(cfg)
    k1, k2 = jax.random.split(rng)
    params = {"embed": embed_init(k1, (cfg.vocab, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab), 0, dt)
    return params


def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    return logical_constraint(x, "batch", "seq", "d_model")


def unembed_matrix(params: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def logits_last(params: dict, x_last: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Decode-path logits for the final position only. x_last: [B, d]."""
    w = unembed_matrix(params, cfg)
    logits = (x_last.astype(jnp.float32)) @ w.astype(jnp.float32)
    return logical_constraint(logits, "batch", "vocab")


def chunked_xent_loss(
    params: dict,
    x: jax.Array,
    labels: jax.Array,
    cfg: ModelConfig,
    *,
    chunk: int = 512,
) -> jax.Array:
    """Mean next-token cross-entropy, computed seq-chunk-wise.

    x: [B, S, d] final hidden states; labels: [B, S] int32 targets.
    """
    w = unembed_matrix(params, cfg)
    b, s, d = x.shape
    chunk = min(chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    pad = n_chunks * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xs = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)  # [n, B, c, d]
    ls = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xl):
        xc, lc = xl
        logits = xc.astype(jnp.float32) @ w.astype(jnp.float32)  # [B, c, V]
        logits = logical_constraint(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        valid = lc >= 0
        loss = jnp.where(valid, lse - picked, 0.0)
        return (carry[0] + loss.sum(), carry[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (xs, ls))
    return tot / jnp.maximum(cnt, 1)
