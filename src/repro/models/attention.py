"""Attention: memory-efficient chunked softmax attention (pure XLA) + decode.

Design notes
------------
* ``chunked_attention`` is an online-softmax (flash-style) attention written
  with ``jax.lax.scan`` over KV chunks, each chunk rematerialized in the
  backward pass (``jax.checkpoint``). It never materializes the [Sq, Skv]
  score matrix, which is what lets prefill_32k and train_4k fit in HBM
  without a Pallas dependency in the SPMD dry-run path.
* The Pallas flash kernel (kernels/flash_attention) implements the same
  contract for the TPU hot path; ``attention_impl`` selects it. Both are
  tested against ``reference_attention``.
* GQA is computed by folding query heads into [kv_heads, group] — the KV
  tensors are never repeated.
* Sliding windows and per-layer "global" overrides (Hymba) are expressed as
  data (masks), not control flow, so a scanned layer stack stays homogeneous.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import logical_constraint

NEG_INF = -1e30

# Attention implementation switch. "auto" routes full-sequence attention
# through the Pallas flash kernel on TPU (scores stay in VMEM — §Perf cell 1)
# and through the pure-XLA chunked path elsewhere (CPU tests, the dry-run).
_ATTN_IMPL = "auto"  # auto | xla | pallas


def set_attention_impl(impl: str):
    global _ATTN_IMPL
    assert impl in ("auto", "xla", "pallas")
    _ATTN_IMPL = impl


def _use_pallas(window) -> bool:
    if _ATTN_IMPL == "xla":
        return False
    if _ATTN_IMPL == "pallas":
        return True
    return jax.default_backend() == "tpu"


def _mask(
    q_pos: jax.Array,  # [Sq]
    kv_pos: jax.Array,  # [Skv]
    window: jax.Array | int,  # 0 = full attention; may be per-example data
    causal: bool,
) -> jax.Array:
    """[Sq, Skv] boolean mask (True = attend)."""
    q = q_pos[:, None]
    k = kv_pos[None, :]
    ok = k >= 0  # negative kv positions mark invalid (cold ring-buffer slots)
    if causal:
        ok &= k <= q
    w = jnp.asarray(window)
    ok &= jnp.where(w > 0, k > q - w, True)
    return ok


def reference_attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Skv, Hkv, D]
    v: jax.Array,  # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,
) -> jax.Array:
    """Plain einsum attention — the oracle for kernels and chunked impl."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    scores *= d ** -0.5
    q_pos = jnp.arange(sq) + q_offset
    kv_pos = jnp.arange(skv)
    m = _mask(q_pos, kv_pos, window, causal)
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "kv_chunk", "window_static")
)
def chunked_attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Skv, Hkv, D]
    v: jax.Array,  # [B, Skv, Hkv, D]
    window: jax.Array,  # scalar int32 (0 = full); data so layers stay uniform
    *,
    causal: bool = True,
    kv_chunk: int = 1024,
    window_static: int = -1,  # static window if known (-1: unknown → XLA path)
    q_offset: int | jax.Array = 0,
) -> jax.Array:
    """Online-softmax attention, scanning KV chunks. fp32 accumulators."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    # Pallas routing: window_static >= 0 certifies the traced `window` equals
    # this static value for every layer using this call site (set by the
    # model from its config), which the kernel needs at compile time.
    if window_static >= 0 and sq > 1 and _use_pallas(window_static):
        from repro.kernels.flash_attention.kernel import (
            flash_attention as _flash,
        )

        return _flash(
            q, k, v,
            causal=causal,
            window=window_static,
            interpret=jax.default_backend() != "tpu",
        )
    kv_chunk = min(kv_chunk, skv)
    n_chunks = (skv + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = (q.reshape(b, sq, hkv, g, d) * (d ** -0.5)).astype(q.dtype)
    ks = k.reshape(b, n_chunks, kv_chunk, hkv, d).swapaxes(0, 1)
    vs = v.reshape(b, n_chunks, kv_chunk, hkv, d).swapaxes(0, 1)
    q_pos = jnp.arange(sq) + q_offset

    def chunk_body(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, c_idx = xs
        kv_pos = jnp.arange(kv_chunk) + c_idx * kv_chunk
        valid = kv_pos < skv
        kv_pos = jnp.where(valid, kv_pos, -1)
        # K/V are read in their stored dtype (bf16): the MXU accumulates
        # bf16×bf16 in fp32 internally, so we do NOT request an f32 result —
        # that would make XLA materialize (and on CPU, carry through the
        # layer loop) f32 copies of the cache, doubling HBM traffic. Only the
        # small score tensor is upcast for a stable softmax.
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc).astype(jnp.float32)
        mask = _mask(q_pos, kv_pos, window, causal)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc)
        acc = acc * jnp.exp(m_prev - m_new)[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, sq), jnp.float32),
        jnp.zeros((b, hkv, g, sq, d), jnp.float32),
    )
    xs = (ks, vs, jnp.arange(n_chunks))
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(chunk_body), init, xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # [B, 1, Hq, D] single new-token query
    k_cache: jax.Array,  # [B, S, Hkv, D]
    v_cache: jax.Array,  # [B, S, Hkv, D]
    kv_pos: jax.Array,  # [B, S] absolute position per cache slot (-1 invalid)
    pos: jax.Array,  # [B] current absolute position of the query
    window: jax.Array,  # scalar (0 = full)
) -> jax.Array:
    """One decode step against a (possibly ring-buffered) KV cache.

    No chunking needed: score tensor is [B, Hq, S] which is small relative to
    the cache itself. fp32 softmax.
    """
    b, _, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d) * (d ** -0.5)
    # Cache is read in its stored dtype (bf16); see chunked_attention for why
    # no f32 result is requested. Softmax runs in f32 on the small scores.
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32)
    ok = kv_pos >= 0
    ok &= kv_pos <= pos[:, None]
    w = jnp.asarray(window)
    ok &= jnp.where(w > 0, kv_pos > (pos[:, None] - w), True)
    scores = jnp.where(ok[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention parameter block (QKV + output projection), GQA-aware.
# ---------------------------------------------------------------------------

def attn_init(rng, cfg) -> dict:
    from repro.models.common import dense_init, param_dtype

    d, dh = cfg.d_model, cfg.d_head
    dt = param_dtype(cfg)
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads, dh), 0, dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, dh), 0, dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, dh), 0, dt),
        "wo": dense_init(ks[3], (cfg.n_heads, dh, d), 0, dt).reshape(
            cfg.n_heads, dh, d
        ),
    }


def qkv_project(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = logical_constraint(q, "batch", "seq", "heads", "d_head")
    k = logical_constraint(k, "batch", "seq", "kv_heads", "d_head")
    v = logical_constraint(v, "batch", "seq", "kv_heads", "d_head")
    return q, k, v


def out_project(params: dict, attn_out: jax.Array, cfg) -> jax.Array:
    out = jnp.einsum("bshk,hkd->bsd", attn_out, params["wo"])
    return logical_constraint(out, "batch", "seq", "d_model")
