"""Recurrent sequence mixers: Mamba (selective SSM), mLSTM, sLSTM.

Each mixer ships two forms:
  * a *sequential* reference (``lax.scan`` over time) — the correctness oracle;
  * a *chunkwise-parallel* form (associative scan / intra-chunk attention with
    log-space gate stabilization) — the TPU-native implementation used by the
    models. Chunk boundaries carry the recurrent state, so memory is
    O(S/chunk · state) instead of O(S · state).

All recurrences run in fp32 regardless of model dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

F32 = jnp.float32


# ===========================================================================
# Mamba (selective SSM) — used by Hymba's SSM heads.  State: h [B, D, N].
# ===========================================================================

def mamba_init(rng, d_model: int, d_inner: int, n_state: int, conv_k: int, dtype) -> dict:
    ks = jax.random.split(rng, 7)
    dt_rank = max(1, d_model // 16)
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner), 0, dtype),
        "conv_w": dense_init(ks[1], (conv_k, d_inner), 0, F32) * 0.5,
        "x_dt": dense_init(ks[2], (d_inner, dt_rank), 0, dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_inner), 0, F32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, d_inner))).astype(F32),
        "x_bc": dense_init(ks[4], (d_inner, 2 * n_state), 0, dtype),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n_state + 1, dtype=F32), (d_inner, 1))),
        "d_skip": jnp.ones((d_inner,), F32),
        "out_proj": dense_init(ks[5], (d_inner, d_model), 0, dtype),
    }


def _mamba_gates(params, x):
    """x: [B, S, d_model] -> (u [B,S,D] conv'd+silu input, z gate, dt, Bmat,
    Cmat, u_raw pre-conv input — the decode conv history)."""
    xz = x @ params["in_proj"]
    u_raw, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv over time
    k = params["conv_w"].shape[0]
    u32 = u_raw.astype(F32)
    pad = jnp.pad(u32, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(pad[:, i : i + u_raw.shape[1]] * params["conv_w"][i] for i in range(k))
    u = jax.nn.silu(conv)
    dt = jax.nn.softplus(
        (u @ params["x_dt"].astype(F32)) @ params["dt_proj"] + params["dt_bias"]
    )  # [B,S,D]
    bc = u @ params["x_bc"].astype(F32)
    bmat, cmat = jnp.split(bc, 2, axis=-1)  # [B,S,N] each
    return u, z, dt, bmat, cmat, u_raw


def _mamba_scan_chunked(u, dt, bmat, cmat, a_log, h0, chunk: int):
    """Diagonal SSM scan: h_t = exp(dt_t·A)·h_{t-1} + dt_t·B_t·u_t,
    y_t = C_t·h_t. Chunked: outer scan carries h, inner associative scan."""
    b, s, d = u.shape
    n = bmat.shape[-1]
    a = -jnp.exp(a_log)  # [D, N], negative for stability
    n_chunks = max(1, s // chunk)
    chunk = s // n_chunks if s % n_chunks == 0 else chunk
    if s % chunk:
        pad = chunk - s % chunk
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    s_pad = u.shape[1]
    nc = s_pad // chunk

    def reshape(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    uc, dtc, bc, cc = map(reshape, (u, dt, bmat, cmat))

    def chunk_body(h, xs):
        u_, dt_, b_, c_ = xs  # [B, c, ...]
        decay = jnp.exp(dt_[..., None] * a)  # [B,c,D,N]
        inp = (dt_ * u_)[..., None] * b_[:, :, None, :]  # [B,c,D,N]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        acc_a, acc_b = jax.lax.associative_scan(combine, (decay, inp), axis=1)
        h_all = acc_a * h[:, None] + acc_b  # [B,c,D,N]
        y = jnp.einsum("bcdn,bcn->bcd", h_all, c_)
        return h_all[:, -1], y

    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, (uc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(b, s_pad, d)[:, :s]
    return y, h_last


def mamba_apply(params, x, *, chunk: int = 64):
    """x: [B, S, d_model] -> [B, S, d_model]; fresh state."""
    u, z, dt, bmat, cmat, _ = _mamba_gates(params, x)
    b = x.shape[0]
    d, n = params["a_log"].shape
    h0 = jnp.zeros((b, d, n), F32)
    y, _ = _mamba_scan_chunked(u, dt, bmat, cmat, params["a_log"], h0, chunk)
    y = y + u * params["d_skip"]
    y = y * jax.nn.silu(z.astype(F32))
    return (y.astype(x.dtype)) @ params["out_proj"]


def mamba_init_state(params, batch: int) -> dict:
    d, n = params["a_log"].shape
    k = params["conv_w"].shape[0]
    return {
        "h": jnp.zeros((batch, d, n), F32),
        "conv": jnp.zeros((batch, k - 1, d), F32),
    }


def mamba_decode_step(params, state, x_t):
    """x_t: [B, d_model] one token. Returns (y [B, d_model], new state)."""
    xz = x_t @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)
    k = params["conv_w"].shape[0]
    hist = jnp.concatenate([state["conv"], u.astype(F32)[:, None]], axis=1)  # [B,k,D]
    conv = jnp.einsum("bkd,kd->bd", hist, params["conv_w"])
    u_ = jax.nn.silu(conv)
    dt = jax.nn.softplus(
        (u_ @ params["x_dt"].astype(F32)) @ params["dt_proj"] + params["dt_bias"]
    )
    bc = u_ @ params["x_bc"].astype(F32)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt[..., None] * a)  # [B,D,N]
    h = decay * state["h"] + (dt * u_)[..., None] * bmat[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cmat) + u_ * params["d_skip"]
    y = y * jax.nn.silu(z.astype(F32))
    new_state = {"h": h, "conv": hist[:, 1:]}
    return (y.astype(x_t.dtype)) @ params["out_proj"], new_state


# ===========================================================================
# mLSTM (xLSTM matrix-memory cell). State: C [B,H,Dh,Dh], n [B,H,Dh], m [B,H].
# ===========================================================================

def mlstm_init(rng, d_model: int, n_heads: int, d_head: int, dtype) -> dict:
    ks = jax.random.split(rng, 6)
    dh = n_heads * d_head
    return {
        "wq": dense_init(ks[0], (d_model, n_heads, d_head), 0, dtype),
        "wk": dense_init(ks[1], (d_model, n_heads, d_head), 0, dtype),
        "wv": dense_init(ks[2], (d_model, n_heads, d_head), 0, dtype),
        "w_i": dense_init(ks[3], (d_model, n_heads), 0, F32) * 0.1,
        "w_f": dense_init(ks[4], (d_model, n_heads), 0, F32) * 0.1,
        "f_bias": jnp.full((n_heads,), 3.0, F32),  # start remembering
        "w_o": dense_init(ks[5], (d_model, dh), 0, dtype),
    }


def _mlstm_qkvif(params, x):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]).astype(F32)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"]).astype(F32)
    k = k * (k.shape[-1] ** -0.5)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"]).astype(F32)
    i_raw = (x.astype(F32) @ params["w_i"])  # [B,S,H]
    f_raw = (x.astype(F32) @ params["w_f"]) + params["f_bias"]
    log_f = jax.nn.log_sigmoid(f_raw)  # sigmoid forget gate, log-space
    return q, k, v, i_raw, log_f


def mlstm_sequential(params, x):
    """Reference: exact recurrence, scan over time. [B,S,d]->[B,S,H*Dh]."""
    q, k, v, i_raw, log_f = _mlstm_qkvif(params, x)
    b, s, h, dh = q.shape
    c0 = jnp.zeros((b, h, dh, dh), F32)
    n0 = jnp.zeros((b, h, dh), F32)
    m0 = jnp.full((b, h), -1e30, F32)

    def step(carry, xs):
        c, n, m = carry
        qt, kt, vt, it, lft = xs  # [b,h,dh] / [b,h]
        m_new = jnp.maximum(lft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(lft + m - m_new)
        c = f_p[..., None, None] * c + i_p[..., None, None] * (
            vt[..., :, None] * kt[..., None, :]
        )
        n = f_p[..., None] * n + i_p[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", c, qt)
        den = jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt))
        y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (c, n, m_new), y

    sw = lambda t: t.swapaxes(0, 1)
    (_, _, _), ys = jax.lax.scan(
        step, (c0, n0, m0), (sw(q), sw(k), sw(v), sw(i_raw), sw(log_f))
    )
    return ys.swapaxes(0, 1).reshape(b, s, h * dh)


def _mlstm_chunk(carry, xs):
    """One chunk of the chunkwise-parallel mLSTM. carry: (C, n, m)."""
    c_in, n_in, m_in = carry
    q, k, v, i_raw, log_f = xs  # [B,c,H,*] / [B,c,H]
    b, c_len, h, dh = q.shape
    # Cumulative log forget within chunk (inclusive).
    f_cum = jnp.cumsum(log_f, axis=1)  # [B,c,H]
    # Stabilizer: m_t = max(m_in + F_t, cummax_s≤t (i_s + F_t - F_s))
    #           = F_t + max(m_in, cummax(i_s - F_s))
    i_shift = i_raw - f_cum  # i_s - F_s
    run_max = jax.lax.associative_scan(jnp.maximum, i_shift, axis=1)
    m_t = f_cum + jnp.maximum(m_in[:, None], run_max)  # [B,c,H]
    # Intra-chunk "attention" weights: w_ts = exp(i_s + F_t - F_s - m_t), s<=t
    logw = (
        i_shift[:, None, :, :]  # s axis -> dim2
        + f_cum[:, :, None, :]  # t axis -> dim1
        - m_t[:, :, None, :]
    )  # [B, t, s, H]
    causal = jnp.tril(jnp.ones((c_len, c_len), bool))
    w = jnp.where(causal[None, :, :, None], jnp.exp(logw), 0.0)
    scores = jnp.einsum("bthk,bshk->btsh", q, k)
    inter = jnp.einsum("btsh,btsh,bshk->bthk", scores, w, v)
    n_inter = jnp.einsum("btsh,bshk->bthk", w, k)
    # Contribution of the carried state: exp(m_in + F_t - m_t) * (C_in·q)
    # C[i,j] = v_i k_j → y_i = Σ_j C[i,j] q_j: contract C's SECOND index.
    decay0 = jnp.exp(m_in[:, None] + f_cum - m_t)  # [B,c,H]
    qc = jnp.einsum("bthk,bhjk->bthj", q, c_in)  # (C_in·q)_j
    num = inter + decay0[..., None] * qc
    nq = jnp.einsum("bthk,bhk->bth", q, n_in)
    den = jnp.abs(jnp.einsum("bthk,bthk->bth", n_inter, q) + decay0 * nq)
    y = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
    # ---- carry update to the end of chunk ----
    m_end = m_t[:, -1]  # [B,H]
    f_total = f_cum[:, -1]
    wc = jnp.exp(i_shift + f_total[:, None] - m_end[:, None])  # [B,c,H]
    c_new = jnp.exp(m_in + f_total - m_end)[..., None, None] * c_in + jnp.einsum(
        "bsh,bshi,bshj->bhij", wc, v, k
    )
    n_new = jnp.exp(m_in + f_total - m_end)[..., None] * n_in + jnp.einsum(
        "bsh,bshk->bhk", wc, k
    )
    return (c_new, n_new, m_end), y


def mlstm_chunked(params, x, *, chunk: int = 128, state=None):
    """Chunkwise-parallel mLSTM. [B,S,d] -> ([B,S,H*Dh], final_state)."""
    q, k, v, i_raw, log_f = _mlstm_qkvif(params, x)
    b, s, h, dh = q.shape
    if state is None:
        state = mlstm_init_state_raw(b, h, dh)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        ext = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v, i_raw = map(ext, (q, k, v, i_raw))
        log_f = ext(log_f)
    nc = q.shape[1] // chunk

    def reshape(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(reshape, (q, k, v, i_raw, log_f)))
    state, ys = jax.lax.scan(jax.checkpoint(_mlstm_chunk), state, xs)
    y = ys.swapaxes(0, 1).reshape(b, -1, h * dh)[:, :s]
    return y, state


def mlstm_init_state_raw(b, h, dh):
    return (
        jnp.zeros((b, h, dh, dh), F32),
        jnp.zeros((b, h, dh), F32),
        jnp.full((b, h), -1e30, F32),
    )


def mlstm_apply(params, x, *, chunk: int = 128):
    """[B,S,d_model] -> [B,S,H*Dh] (output projection applied by the block)."""
    y, _ = mlstm_chunked(params, x, chunk=chunk)
    return y.astype(x.dtype)


def mlstm_decode_step(params, state, x_t):
    """x_t: [B, d_model]. Returns (y [B, H*Dh], new_state)."""
    q, k, v, i_raw, log_f = _mlstm_qkvif(params, x_t[:, None])
    c, n, m = state
    qt, kt, vt = q[:, 0], k[:, 0], v[:, 0]
    it, lft = i_raw[:, 0], log_f[:, 0]
    m_new = jnp.maximum(lft + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(lft + m - m_new)
    c = f_p[..., None, None] * c + i_p[..., None, None] * (
        vt[..., :, None] * kt[..., None, :]
    )
    n = f_p[..., None] * n + i_p[..., None] * kt
    num = jnp.einsum("bhij,bhj->bhi", c, qt)
    den = jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    b, h, dh = y.shape
    return y.reshape(b, h * dh).astype(x_t.dtype), (c, n, m_new)


# ===========================================================================
# sLSTM (scalar cell with exponential gating + per-head recurrence).
# State: (h, c, n, m) each [B, H, Dh].
# ===========================================================================

def slstm_init(rng, d_model: int, n_heads: int, d_head: int, dtype) -> dict:
    ks = jax.random.split(rng, 9)
    dh_total = n_heads * d_head

    def w(key):
        return dense_init(key, (d_model, n_heads, d_head), 0, F32)

    def r(key):
        return dense_init(key, (n_heads, d_head, d_head), 1, F32)

    return {
        "wz": w(ks[0]), "wi": w(ks[1]), "wf": w(ks[2]), "wo": w(ks[3]),
        "rz": r(ks[4]), "ri": r(ks[5]), "rf": r(ks[6]), "ro": r(ks[7]),
        "f_bias": jnp.full((n_heads, d_head), 3.0, F32),
        "out_proj": dense_init(ks[8], (dh_total, d_model), 0, dtype),
    }


def slstm_init_state(batch: int, n_heads: int, d_head: int):
    z = jnp.zeros((batch, n_heads, d_head), F32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": z - 1e30}


def _slstm_step(params, state, x_t):
    """x_t: [B, H, Dh]-projected inputs dict. One recurrence step."""
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]

    def rec(wname, rname):
        return x_t[wname] + jnp.einsum("bhk,hkj->bhj", h, params[rname])

    z = jnp.tanh(rec("z", "rz"))
    i_raw = rec("i", "ri")
    f_raw = rec("f", "rf") + params["f_bias"]
    o = jax.nn.sigmoid(rec("o", "ro"))
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_apply(params, x, *, state=None):
    """x: [B,S,d_model] -> ([B,S,d_model], final_state). Sequential over S —
    sLSTM's memory mixing is inherently serial (xLSTM §2.1); it appears only
    in a minority of xLSTM layers by design."""
    b, s, _ = x.shape
    h_, dh = params["f_bias"].shape
    if state is None:
        state = slstm_init_state(b, h_, dh)
    proj = {
        name: jnp.einsum("bsd,dhk->bshk", x.astype(F32), params["w" + name])
        for name in ("z", "i", "f", "o")
    }

    def step(st, xs):
        st = _slstm_step(params, st, xs)
        return st, st["h"]

    xs = {k_: v.swapaxes(0, 1) for k_, v in proj.items()}
    state, hs = jax.lax.scan(step, state, xs)
    y = hs.swapaxes(0, 1).reshape(b, s, h_ * dh)
    return (y.astype(x.dtype)) @ params["out_proj"], state


def slstm_decode_step(params, state, x_t):
    """x_t: [B, d_model]. Returns (y [B, d_model], new state)."""
    proj = {
        name: jnp.einsum("bd,dhk->bhk", x_t.astype(F32), params["w" + name])
        for name in ("z", "i", "f", "o")
    }
    state = _slstm_step(params, state, proj)
    b = x_t.shape[0]
    y = state["h"].reshape(b, -1)
    return (y.astype(x_t.dtype)) @ params["out_proj"], state
