"""Whisper-large-v3 backbone: encoder-decoder transformer.

Per the assignment, the conv/mel frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings [B, S_enc, d] (post-conv), with
S_enc = seq_len // cfg.encoder_seq_ratio. The transformer backbone (32
encoder layers with bidirectional self-attn, 32 decoder layers with causal
self-attn + cross-attn) is real.

Whisper uses LayerNorm + GELU and learned decoder positions (no RoPE);
encoder positions are sinusoidal, computed on the fly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models.attention import (
    attn_init,
    chunked_attention,
    decode_attention,
    out_project,
    qkv_project,
)
from repro.sharding.rules import logical_constraint


def _sinusoidal(s: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(s)[:, None]
    dim = jnp.arange(d // 2)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln(params, x, cfg):
    return C.layernorm_apply(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------

def _enc_block_init(rng, cfg):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": C.layernorm_init(cfg.d_model),
        "attn": attn_init(k1, cfg),
        "ln2": C.layernorm_init(cfg.d_model),
        "mlp": C.mlp_init(k2, cfg),
    }


def _dec_block_init(rng, cfg):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": C.layernorm_init(cfg.d_model),
        "self_attn": attn_init(k1, cfg),
        "ln_x": C.layernorm_init(cfg.d_model),
        "cross_attn": attn_init(k2, cfg),
        "ln2": C.layernorm_init(cfg.d_model),
        "mlp": C.mlp_init(k3, cfg),
    }


def init_params(rng, cfg: ModelConfig) -> dict:
    k_emb, k_enc, k_dec, k_pos = jax.random.split(rng, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embedding": C.embedding_init(k_emb, cfg),
        "pos_embed": C.embed_init(k_pos, (cfg.max_position, cfg.d_model), C.param_dtype(cfg)),
        "encoder": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "enc_norm": C.layernorm_init(cfg.d_model),
        "decoder": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "final_norm": C.layernorm_init(cfg.d_model),
    }


def encode(params, frames: jax.Array, cfg: ModelConfig, *, remat=True):
    """frames: [B, S_enc, d] stub embeddings -> encoder states."""
    s = frames.shape[1]
    x = frames + _sinusoidal(s, cfg.d_model).astype(frames.dtype)
    zero_window = jnp.asarray(0, jnp.int32)
    positions = jnp.arange(s)

    def body(x, lp):
        h = _ln(lp["ln1"], x, cfg)
        q, k, v = qkv_project(lp["attn"], h, cfg)
        attn = chunked_attention(q, k, v, zero_window, causal=False)
        x = x + out_project(lp["attn"], attn, cfg)
        h2 = _ln(lp["ln2"], x, cfg)
        x = x + C.mlp_apply(lp["mlp"], h2, cfg)
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return _ln(params["enc_norm"], x, cfg)


def _dec_block(lp, x, enc_kv, positions, cfg, self_kv=None, decode_ctx=None):
    """Decoder block; full-seq when decode_ctx is None, else 1-token."""
    enc_k, enc_v = enc_kv
    zero_window = jnp.asarray(0, jnp.int32)
    h = _ln(lp["ln1"], x, cfg)
    q, k, v = qkv_project(lp["self_attn"], h, cfg)
    if decode_ctx is None:
        attn = chunked_attention(q, k, v, zero_window, causal=True)
    else:
        kc, vc, kv_pos, pos, slot = decode_ctx
        b = x.shape[0]
        bidx = jnp.arange(b)
        kc = kc.at[bidx, slot].set(k[:, 0])
        vc = vc.at[bidx, slot].set(v[:, 0])
        attn = decode_attention(q, kc, vc, kv_pos, pos, zero_window)
        k, v = kc, vc
    x = x + out_project(lp["self_attn"], attn, cfg)
    hx = _ln(lp["ln_x"], x, cfg)
    qx = jnp.einsum("bsd,dhk->bshk", hx, lp["cross_attn"]["wq"])
    cross = chunked_attention(qx, enc_k, enc_v, zero_window, causal=False)
    x = x + out_project(lp["cross_attn"], cross, cfg)
    h2 = _ln(lp["ln2"], x, cfg)
    x = x + C.mlp_apply(lp["mlp"], h2, cfg)
    return x, (k, v)


def _cross_kv(params_dec, enc_out, cfg):
    """Precompute per-layer cross-attention K/V from encoder output."""

    def body(_, lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params_dec)
    return ks, vs


def forward_hidden(params, tokens, frames, cfg: ModelConfig, *, remat=True):
    enc_out = encode(params, frames, cfg, remat=remat)
    enc_ks, enc_vs = _cross_kv(params["decoder"], enc_out, cfg)
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = C.embed_tokens(params["embedding"], tokens, cfg)
    x = x + jnp.take(params["pos_embed"], positions, axis=0)[None]

    def body(x, xs):
        lp, ek, ev = xs
        x, _ = _dec_block(lp, x, (ek, ev), positions, cfg)
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["decoder"], enc_ks, enc_vs))
    return _ln(params["final_norm"], x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    x = forward_hidden(params, batch["tokens"], batch["extra_embeds"], cfg)
    return C.chunked_xent_loss(params["embedding"], x, batch["labels"], cfg)


# -- serving ---------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    dt = C.param_dtype(cfg)
    l = cfg.n_layers
    s_enc = max(1, seq_len // cfg.encoder_seq_ratio)
    kv = (l, batch, seq_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(kv, dt),
        "v": jnp.zeros(kv, dt),
        "kv_pos": jnp.full((batch, seq_len), -1, jnp.int32),
        "cross_k": jnp.zeros((l, batch, s_enc, cfg.n_kv_heads, cfg.d_head), dt),
        "cross_v": jnp.zeros((l, batch, s_enc, cfg.n_kv_heads, cfg.d_head), dt),
    }


def prefill(params, tokens, frames, cfg: ModelConfig, *, max_len: int | None = None):
    enc_out = encode(params, frames, cfg)
    enc_ks, enc_vs = _cross_kv(params["decoder"], enc_out, cfg)
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = C.embed_tokens(params["embedding"], tokens, cfg)
    x = x + jnp.take(params["pos_embed"], positions, axis=0)[None]

    def body(x, xs):
        lp, ek, ev = xs
        x, kv = _dec_block(lp, x, (ek, ev), positions, cfg)
        return x, kv

    x, (ks, vs) = jax.lax.scan(jax.checkpoint(body), x, (params["decoder"], enc_ks, enc_vs))
    x = _ln(params["final_norm"], x, cfg)
    s_alloc = max_len or s
    if s_alloc > s:  # decode headroom
        pad = s_alloc - s
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.concatenate([jnp.arange(s), jnp.full((pad,), -1, jnp.int32)])
        kv_pos = jnp.broadcast_to(kv_pos, (b, s_alloc))
    else:
        kv_pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    cache = {
        "k": ks,
        "v": vs,
        "kv_pos": kv_pos,
        "cross_k": enc_ks,
        "cross_v": enc_vs,
    }
    return C.logits_last(params["embedding"], x[:, -1], cfg), cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    b = tokens.shape[0]
    x = C.embed_tokens(params["embedding"], tokens[:, None], cfg)
    x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None]
    s_alloc = cache["k"].shape[2]
    slot = pos % s_alloc
    kv_pos = cache["kv_pos"].at[jnp.arange(b), slot].set(pos)
    zero_window = jnp.asarray(0, jnp.int32)

    def body(x, xs):
        lp, kc, vc, ek, ev = xs
        x, (k_new, v_new) = _dec_block(
            lp, x, (ek, ev), None, cfg,
            decode_ctx=(kc, vc, kv_pos, pos, slot),
        )
        return x, (k_new, v_new)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["decoder"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    x = _ln(params["final_norm"], x, cfg)
    logits = C.logits_last(params["embedding"], x[:, 0], cfg)
    new_cache = dict(cache, k=ks, v=vs, kv_pos=kv_pos)
    return logits, new_cache
