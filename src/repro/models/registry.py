"""Arch registry: ``--arch <id>`` → config + a uniform model API.

The API surface consumed by the launcher / dry-run / trainer / server:

    api = get_model(cfg)
    params = api.init_params(rng)
    loss   = api.loss_fn(params, batch)            # batch from api.train_batch_specs
    logits, cache = api.prefill(params, **inputs)  # inputs from api.prefill_specs
    logits, cache = api.decode_step(params, cache, tokens, pos)
    cache  = api.init_cache(batch, seq_len)

``input_specs(shape)`` returns jax.ShapeDtypeStruct stand-ins (weak-type
correct, no allocation) for every model input of the given shape cell — the
dry-run lowers against these.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

ARCH_MODULES = {
    "granite-20b": "granite_20b",
    "internlm2-1.8b": "internlm2_1_8b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "deepseek-7b": "deepseek_7b",
    "xlstm-125m": "xlstm_125m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "hymba-1.5b": "hymba_1_5b",
    "llava-next-34b": "llava_next_34b",
    "whisper-large-v3": "whisper_large_v3",
}

ALL_ARCHS = tuple(ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    reductions: dict[str, Any] = dict(
        n_layers=4 if (cfg.slstm_every or cfg.global_attn_layers) else 2,
        d_model=128,
        n_heads=4,
        n_kv_heads=1 if cfg.n_kv_heads == 1 else (4 if cfg.n_kv_heads == cfg.n_heads else 2),
        d_ff=64 if cfg.n_experts else 256,
        vocab=512,
        max_position=4096,
        dtype="float32",
    )
    if cfg.n_experts:
        # capacity_factor high enough that the tiny smoke batches never drop
        # tokens — keeps prefill/decode numerics comparable in tests.
        reductions.update(n_experts=8, top_k=min(cfg.top_k, 2), capacity_factor=8.0)
    if cfg.sliding_window:
        reductions.update(sliding_window=16)
    if cfg.global_attn_layers:
        reductions.update(global_attn_layers=(0, 3))
    if cfg.n_encoder_layers:
        reductions.update(n_encoder_layers=2)
    return dataclasses.replace(cfg, **reductions)


# ---------------------------------------------------------------------------

def _seq_split(cfg: ModelConfig, seq_len: int) -> tuple[int, int]:
    """(frontend_tokens, text_tokens) for stub-frontend archs."""
    if cfg.frontend == "vision_patches":
        s_img = int(seq_len * cfg.frontend_tokens_ratio)
        return s_img, seq_len - s_img
    if cfg.frontend == "audio_frames":
        return max(1, seq_len // cfg.encoder_seq_ratio), seq_len
    return 0, seq_len


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init_params: Callable
    loss_fn: Callable          # (params, batch) -> scalar
    prefill: Callable          # (params, **inputs) -> (logits, cache)
    decode_step: Callable      # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Callable       # (batch, seq_len) -> cache pytree

    # -- input specs (ShapeDtypeStruct, no allocation) ---------------------
    def train_batch_specs(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        s_front, s_text = _seq_split(cfg, s)
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
            "labels": jax.ShapeDtypeStruct(
                (b, s if cfg.frontend == "audio_frames" else s_text), jnp.int32
            ),
        }
        if cfg.frontend != "none":
            specs["extra_embeds"] = jax.ShapeDtypeStruct(
                (b, s_front, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.frontend == "vision_patches":
            specs["labels"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        return specs

    def prefill_specs(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        s_front, s_text = _seq_split(cfg, s)
        specs = {"tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32)}
        if cfg.frontend == "vision_patches":
            specs["extra_embeds"] = jax.ShapeDtypeStruct(
                (b, s_front, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.frontend == "audio_frames":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, s_front, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return specs

    def decode_specs(self, shape: ShapeConfig) -> dict:
        b = shape.global_batch
        cache = jax.eval_shape(lambda: self.init_cache(b, shape.seq_len))
        return {
            "cache": cache,
            "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        }

    def make_train_batch(self, shape: ShapeConfig, rng) -> dict:
        """Materialize a random batch matching train_batch_specs (tests)."""
        specs = self.train_batch_specs(shape)
        keys = jax.random.split(rng, len(specs))
        out = {}
        for k_, (name, spec) in zip(keys, sorted(specs.items())):
            if spec.dtype == jnp.int32:
                out[name] = jax.random.randint(k_, spec.shape, 0, self.cfg.vocab)
            else:
                out[name] = jax.random.normal(k_, spec.shape, spec.dtype) * 0.02
        return out


def get_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models import transformer as M

        def prefill(params, tokens, extra_embeds=None, max_len=None):
            return M.prefill(
                params, tokens, cfg, extra_embeds=extra_embeds, max_len=max_len
            )

        return ModelApi(
            cfg=cfg,
            init_params=lambda rng: M.init_params(rng, cfg),
            loss_fn=lambda p, b: M.loss_fn(p, b, cfg),
            prefill=prefill,
            decode_step=lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, s: M.init_cache(cfg, b, s),
        )
    if fam == "ssm":
        from repro.models import xlstm as M

        return ModelApi(
            cfg=cfg,
            init_params=lambda rng: M.init_params(rng, cfg),
            loss_fn=lambda p, b: M.loss_fn(p, b, cfg),
            prefill=lambda params, tokens, max_len=None: M.prefill(params, tokens, cfg),
            decode_step=lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, s: M.init_cache(cfg, b, s),
        )
    if fam == "hybrid":
        from repro.models import hymba as M

        return ModelApi(
            cfg=cfg,
            init_params=lambda rng: M.init_params(rng, cfg),
            loss_fn=lambda p, b: M.loss_fn(p, b, cfg),
            prefill=lambda params, tokens, max_len=None: M.prefill(
                params, tokens, cfg, max_len=max_len
            ),
            decode_step=lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, s: M.init_cache(cfg, b, s),
        )
    if fam == "audio":
        from repro.models import whisper as M

        return ModelApi(
            cfg=cfg,
            init_params=lambda rng: M.init_params(rng, cfg),
            loss_fn=lambda p, b: M.loss_fn(p, b, cfg),
            prefill=lambda params, tokens, frames, max_len=None: M.prefill(
                params, tokens, frames, cfg, max_len=max_len
            ),
            decode_step=lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, s: M.init_cache(cfg, b, s),
        )
    raise ValueError(f"unknown family {fam}")
