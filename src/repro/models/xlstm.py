"""xLSTM LM (xlstm-125m): interleaved mLSTM (matrix memory) and sLSTM blocks.

Layer schedule: every ``cfg.slstm_every``-th layer is an sLSTM block, the rest
are mLSTM (the assignment's "sLSTM + mLSTM blocks"). mLSTM blocks use the
xLSTM paper's pre-up-projection (pf=2); sLSTM blocks use a post gated FFN.

Serving state is O(1) in context length — this is the assigned long_500k
arch par excellence. There is no KV cache, hence (per DESIGN.md
§Arch-applicability) nothing for the Wolf block manager to manage.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models import ssm

# Block kinds are static Python data (tuple), so the two scans stay separate.


def layer_kinds(cfg: ModelConfig) -> tuple[str, ...]:
    k = cfg.slstm_every
    return tuple(
        "slstm" if (k and (i + 1) % k == 0) else "mlstm" for i in range(cfg.n_layers)
    )


def _mlstm_block_init(rng, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = 2 * d  # pf = 2 up-projection
    dt = C.param_dtype(cfg)
    ks = jax.random.split(rng, 4)
    return {
        "ln": C.rmsnorm_init(d),
        "up": C.dense_init(ks[0], (d, 2 * d_in), 0, dt),  # -> (x_in, gate)
        "cell": ssm.mlstm_init(ks[1], d_in, cfg.n_heads, d_in // cfg.n_heads, dt),
        "down": C.dense_init(ks[2], (d_in, d), 0, dt),
    }


def _mlstm_block(params, x, cfg: ModelConfig, state=None):
    h = C.rmsnorm_apply(params["ln"], x, cfg.norm_eps)
    up = h @ params["up"]
    x_in, gate = jnp.split(up, 2, axis=-1)
    y, new_state = ssm.mlstm_chunked(params["cell"], x_in, state=state)
    y = y.astype(x.dtype) * jax.nn.silu(gate)
    return x + y @ params["down"], new_state


def _mlstm_block_decode(params, x_t, state, cfg: ModelConfig):
    h = C.rmsnorm_apply(params["ln"], x_t, cfg.norm_eps)
    up = h @ params["up"]
    x_in, gate = jnp.split(up, 2, axis=-1)
    y, new_state = ssm.mlstm_decode_step(params["cell"], state, x_in)
    y = y * jax.nn.silu(gate)
    return x_t + y @ params["down"], new_state


def _slstm_block_init(rng, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dt = C.param_dtype(cfg)
    ks = jax.random.split(rng, 3)
    d_ff = int(d * 4 / 3 / 64) * 64 or d
    return {
        "ln": C.rmsnorm_init(d),
        "cell": ssm.slstm_init(ks[0], d, cfg.n_heads, d // cfg.n_heads, dt),
        "ln2": C.rmsnorm_init(d),
        "ffn_gate": C.dense_init(ks[1], (d, d_ff), 0, dt),
        "ffn_up": C.dense_init(ks[1], (d, d_ff), 0, dt),
        "ffn_down": C.dense_init(ks[2], (d_ff, d), 0, dt),
    }


def _slstm_block(params, x, cfg: ModelConfig, state=None):
    h = C.rmsnorm_apply(params["ln"], x, cfg.norm_eps)
    y, new_state = ssm.slstm_apply(params["cell"], h, state=state)
    x = x + y
    h2 = C.rmsnorm_apply(params["ln2"], x, cfg.norm_eps)
    ff = jax.nn.silu(h2 @ params["ffn_gate"]) * (h2 @ params["ffn_up"])
    return x + ff @ params["ffn_down"], new_state


def init_params(rng, cfg: ModelConfig) -> dict:
    kinds = layer_kinds(cfg)
    k_emb, *layer_keys = jax.random.split(rng, cfg.n_layers + 1)
    layers = []
    for kind, k in zip(kinds, layer_keys):
        init = _mlstm_block_init if kind == "mlstm" else _slstm_block_init
        layers.append(init(k, cfg))
    return {
        "embedding": C.embedding_init(k_emb, cfg),
        "blocks": layers,  # heterogeneous: plain list, unrolled (12 layers)
        "final_norm": C.rmsnorm_init(cfg.d_model),
    }


def forward_hidden(params, tokens, cfg: ModelConfig, *, remat: bool = True):
    x = C.embed_tokens(params["embedding"], tokens, cfg)
    kinds = layer_kinds(cfg)
    for kind, lp in zip(kinds, params["blocks"]):
        fn = _mlstm_block if kind == "mlstm" else _slstm_block
        if remat:
            fn = jax.checkpoint(fn, static_argnums=(2,))
        x, _ = fn(lp, x, cfg)
    return C.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)


def loss_fn(params, batch, cfg: ModelConfig):
    x = forward_hidden(params, batch["tokens"], cfg)
    return C.chunked_xent_loss(params["embedding"], x, batch["labels"], cfg)


# -- serving (recurrent state instead of KV cache) --------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    del seq_len  # O(1) state
    kinds = layer_kinds(cfg)
    states = []
    for kind in kinds:
        if kind == "mlstm":
            d_in = 2 * cfg.d_model
            states.append(
                {"mlstm": ssm.mlstm_init_state_raw(batch, cfg.n_heads, d_in // cfg.n_heads)}
            )
        else:
            states.append(
                {"slstm": ssm.slstm_init_state(batch, cfg.n_heads, cfg.d_model // cfg.n_heads)}
            )
    return {"states": states}


def prefill(params, tokens, cfg: ModelConfig):
    x = C.embed_tokens(params["embedding"], tokens, cfg)
    kinds = layer_kinds(cfg)
    states = []
    for kind, lp in zip(kinds, params["blocks"]):
        fn = _mlstm_block if kind == "mlstm" else _slstm_block
        x, st = jax.checkpoint(fn, static_argnums=(2,))(lp, x, cfg)
        states.append({kind: st})
    x = C.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = C.logits_last(params["embedding"], x[:, -1], cfg)
    return logits, {"states": states}


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    del pos  # recurrent: position-free
    x = C.embed_tokens(params["embedding"], tokens[:, None], cfg)[:, 0]
    kinds = layer_kinds(cfg)
    new_states = []
    for kind, lp, st in zip(kinds, params["blocks"], cache["states"]):
        if kind == "mlstm":
            x, new = _mlstm_block_decode(lp, x, st["mlstm"], cfg)
            new_states.append({"mlstm": new})
        else:
            h = C.rmsnorm_apply(lp["ln"], x, cfg.norm_eps)
            y, new = ssm.slstm_decode_step(lp["cell"], st["slstm"], h)
            x = x + y
            h2 = C.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
            ff = jax.nn.silu(h2 @ lp["ffn_gate"]) * (h2 @ lp["ffn_up"])
            x = x + ff @ lp["ffn_down"]
            new_states.append({"slstm": new})
    x = C.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = C.logits_last(params["embedding"], x, cfg)
    return logits, {"states": new_states}
