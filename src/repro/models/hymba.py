"""Hymba-1.5B: hybrid-head LM — every layer runs attention heads and Mamba
(SSM) heads *in parallel* on the same input, outputs fused (arXiv:2411.13676).

Faithful points: parallel attn ∥ SSM within a layer; mostly sliding-window
attention with a few global layers (first / middle / last); per-path output
normalization before fusion. Stubbed: meta tokens (noted in DESIGN.md).

All layers are structurally identical → single scanned stack; global-vs-SWA
is per-layer *data* (window schedule).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models import ssm
from repro.models.attention import (
    attn_init,
    chunked_attention,
    decode_attention,
    out_project,
    qkv_project,
)
from repro.models.transformer import cache_alloc_len, window_schedule
from repro.sharding.rules import logical_constraint


def _block_init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 3)
    d = cfg.d_model
    return {
        "ln1": C.rmsnorm_init(d),
        "attn": attn_init(ks[0], cfg),
        "mamba": ssm.mamba_init(ks[1], d, d, cfg.ssm_state, cfg.conv_kernel, C.param_dtype(cfg)),
        "attn_norm": C.rmsnorm_init(d),
        "mamba_norm": C.rmsnorm_init(d),
        "ln2": C.rmsnorm_init(d),
        "mlp": C.mlp_init(ks[2], cfg),
    }


def _fuse(params, attn_out, mamba_out, cfg):
    a = C.rmsnorm_apply(params["attn_norm"], attn_out, cfg.norm_eps)
    m = C.rmsnorm_apply(params["mamba_norm"], mamba_out, cfg.norm_eps)
    return 0.5 * (a + m)


def _block_forward(params, x, positions, window, cfg: ModelConfig):
    h = C.rmsnorm_apply(params["ln1"], x, cfg.norm_eps)
    # attention path
    q, k, v = qkv_project(params["attn"], h, cfg)
    q = C.apply_rope(q, positions, cfg.rope_theta)
    k_r = C.apply_rope(k, positions, cfg.rope_theta)
    attn = chunked_attention(q, k_r, v, window, causal=True)
    attn = out_project(params["attn"], attn, cfg)
    # ssm path (parallel, same input)
    mam = ssm.mamba_apply(params["mamba"], h)
    x = x + _fuse(params, attn, mam, cfg)
    h2 = C.rmsnorm_apply(params["ln2"], x, cfg.norm_eps)
    x = x + C.mlp_apply(params["mlp"], h2, cfg)
    x = logical_constraint(x, "batch", "seq", "d_model")
    return x, (k_r, v)


def init_params(rng, cfg: ModelConfig) -> dict:
    k_emb, k_layers = jax.random.split(rng)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _block_init(k, cfg))(layer_keys)
    return {
        "embedding": C.embedding_init(k_emb, cfg),
        "layers": layers,
        "final_norm": C.rmsnorm_init(cfg.d_model),
    }


def forward_hidden(params, tokens, cfg: ModelConfig, *, collect_kv=False, remat=True):
    x = C.embed_tokens(params["embedding"], tokens, cfg)
    positions = jnp.arange(x.shape[1])
    windows = window_schedule(cfg)

    def body(x, xs):
        lp, win = xs
        x, kv = _block_forward(lp, x, positions, win, cfg)
        return x, kv if collect_kv else None

    body_fn = jax.checkpoint(body) if remat else body
    x, kvs = jax.lax.scan(body_fn, x, (params["layers"], windows))
    x = C.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return (x, kvs) if collect_kv else x


def loss_fn(params, batch, cfg: ModelConfig):
    x = forward_hidden(params, batch["tokens"], cfg)
    return C.chunked_xent_loss(params["embedding"], x, batch["labels"], cfg)


# -- serving: KV cache (attention) + recurrent state (mamba) ---------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    s_alloc = cache_alloc_len(cfg, seq_len)
    dt = C.param_dtype(cfg)
    l = cfg.n_layers
    d = cfg.d_model
    return {
        "k": jnp.zeros((l, batch, s_alloc, cfg.n_kv_heads, cfg.d_head), dt),
        "v": jnp.zeros((l, batch, s_alloc, cfg.n_kv_heads, cfg.d_head), dt),
        "kv_pos": jnp.full((batch, s_alloc), -1, jnp.int32),
        "ssm_h": jnp.zeros((l, batch, d, cfg.ssm_state), jnp.float32),
        "ssm_conv": jnp.zeros((l, batch, cfg.conv_kernel - 1, d), jnp.float32),
    }


def prefill(params, tokens, cfg: ModelConfig, *, max_len: int | None = None):
    # Full-sequence pass that also extracts KV + final SSM state per layer.
    x = C.embed_tokens(params["embedding"], tokens, cfg)
    b, s = tokens.shape
    positions = jnp.arange(s)
    windows = window_schedule(cfg)

    def body(x, xs):
        lp, win = xs
        h = C.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(lp["attn"], h, cfg)
        q = C.apply_rope(q, positions, cfg.rope_theta)
        k_r = C.apply_rope(k, positions, cfg.rope_theta)
        attn = chunked_attention(q, k_r, v, win, causal=True)
        attn = out_project(lp["attn"], attn, cfg)
        u, z, dtg, bmat, cmat, u_raw = ssm._mamba_gates(lp["mamba"], h)
        h0 = jnp.zeros((b, cfg.d_model, cfg.ssm_state), jnp.float32)
        y, h_last = ssm._mamba_scan_chunked(
            u, dtg, bmat, cmat, lp["mamba"]["a_log"], h0, 64
        )
        y = (y + u * lp["mamba"]["d_skip"]) * jax.nn.silu(z.astype(jnp.float32))
        mam = y.astype(x.dtype) @ lp["mamba"]["out_proj"]
        x = x + _fuse(lp, attn, mam, cfg)
        h2 = C.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
        x = x + C.mlp_apply(lp["mlp"], h2, cfg)
        # decode conv history = PRE-conv raw projected inputs
        conv_state = u_raw[:, -(cfg.conv_kernel - 1):].astype(jnp.float32)
        return x, (k_r, v, h_last, conv_state)

    x, (ks, vs, hs, convs) = jax.lax.scan(
        jax.checkpoint(body), x, (params["layers"], windows)
    )
    x = C.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    s_alloc = cache_alloc_len(cfg, max_len or s)
    if s_alloc > s:  # decode headroom
        pad = s_alloc - s
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.concatenate([jnp.arange(s), jnp.full((pad,), -1, jnp.int32)])
        kv_pos = jnp.broadcast_to(kv_pos, (b, s_alloc))
    else:
        kv_pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    cache = {
        "k": ks, "v": vs, "kv_pos": kv_pos,
        "ssm_h": hs, "ssm_conv": convs,
    }
    logits = C.logits_last(params["embedding"], x[:, -1], cfg)
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    x = C.embed_tokens(params["embedding"], tokens[:, None], cfg)
    b = tokens.shape[0]
    s_alloc = cache["k"].shape[2]
    slot = pos % s_alloc
    kv_pos = cache["kv_pos"].at[jnp.arange(b), slot].set(pos)
    windows = window_schedule(cfg)

    def body(x, xs):
        lp, kc, vc, hc, cc, win = xs
        h = C.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
        q, k, v = qkv_project(lp["attn"], h, cfg)
        pos2d = pos[:, None]
        q = C.apply_rope(q, pos2d, cfg.rope_theta)
        k = C.apply_rope(k, pos2d, cfg.rope_theta)
        bidx = jnp.arange(b)
        kc = kc.at[bidx, slot].set(k[:, 0])
        vc = vc.at[bidx, slot].set(v[:, 0])
        attn = decode_attention(q, kc, vc, kv_pos, pos, win)
        attn = out_project(lp["attn"], attn, cfg)
        mam, new_ssm = ssm.mamba_decode_step(
            lp["mamba"], {"h": hc, "conv": cc}, h[:, 0]
        )
        x = x + _fuse(lp, attn, mam[:, None], cfg)
        h2 = C.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
        x = x + C.mlp_apply(lp["mlp"], h2, cfg)
        return x, (kc, vc, new_ssm["h"], new_ssm["conv"])

    x, (ks, vs, hs, convs) = jax.lax.scan(
        body,
        x,
        (params["layers"], cache["k"], cache["v"], cache["ssm_h"], cache["ssm_conv"], windows),
    )
    x = C.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = C.logits_last(params["embedding"], x[:, 0], cfg)
    return logits, {
        "k": ks, "v": vs, "kv_pos": kv_pos, "ssm_h": hs, "ssm_conv": convs
    }
