"""Decoder-only transformer LM (dense + MoE): granite-20b, internlm2-1.8b,
deepseek-coder-33b, deepseek-7b, llava-next-34b (backbone), olmoe-1b-7b,
mixtral-8x22b.

Layers are stacked and driven by ``jax.lax.scan`` (small HLO, fast compile on
the 512-device dry-run) with per-layer remat. Heterogeneity (sliding-window
vs global layers) is expressed as per-layer *data* (window sizes), never
Python control flow, so the stack stays scannable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models.attention import (
    attn_init,
    chunked_attention,
    decode_attention,
    out_project,
    qkv_project,
)
from repro.models.moe import moe_apply, moe_init
from repro.sharding.rules import logical_constraint


# ---------------------------------------------------------------------------
# Per-layer window schedule (0 = full attention)
# ---------------------------------------------------------------------------

def window_schedule(cfg: ModelConfig) -> jnp.ndarray:
    win = jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    if cfg.global_attn_layers:
        idx = jnp.asarray(cfg.global_attn_layers)
        win = win.at[idx].set(0)
    return win


def cache_alloc_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer allocation: SWA-everywhere archs cap the cache at the
    window size (mixtral long-context); any full-attention layer forces a
    full-length cache."""
    if cfg.sliding_window > 0 and not cfg.global_attn_layers:
        return min(seq_len, cfg.sliding_window)
    return seq_len


# ---------------------------------------------------------------------------
# Norm dispatch
# ---------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig):
    return C.rmsnorm_init(cfg.d_model)


def _norm(params, x, cfg):
    return C.rmsnorm_apply(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------

def block_init(rng, cfg: ModelConfig) -> dict:
    k_attn, k_ffn = jax.random.split(rng)
    params = {
        "ln1": _norm_init(cfg),
        "attn": attn_init(k_attn, cfg),
        "ln2": _norm_init(cfg),
    }
    if cfg.n_experts:
        params["moe"] = moe_init(k_ffn, cfg)
    else:
        params["mlp"] = C.mlp_init(k_ffn, cfg)
    return params


def _ffn(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.n_experts:
        return moe_apply(params["moe"], x, cfg)
    return C.mlp_apply(params["mlp"], x, cfg)


def block_forward(
    params: dict,
    x: jax.Array,  # [B, S, d]
    positions: jax.Array,  # [S]
    window: jax.Array,  # scalar int32
    cfg: ModelConfig,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence (train / prefill) block. Returns (x, (k, v)) so prefill
    can build the KV cache."""
    h = _norm(params["ln1"], x, cfg)
    q, k, v = qkv_project(params["attn"], h, cfg)
    if cfg.use_rope:
        q = C.apply_rope(q, positions, cfg.rope_theta)
        k = C.apply_rope(k, positions, cfg.rope_theta)
    # uniform-window archs can certify the static window → Pallas-routable
    ws = cfg.sliding_window if not cfg.global_attn_layers else -1
    attn = chunked_attention(q, k, v, window, causal=True, window_static=ws)
    x = x + out_project(params["attn"], attn, cfg)
    h2 = _norm(params["ln2"], x, cfg)
    x = x + _ffn(params, h2, cfg)
    x = logical_constraint(x, "batch", "seq", "d_model")
    return x, (k, v)


def block_decode(
    params: dict,
    x: jax.Array,  # [B, 1, d]
    k_cache: jax.Array,  # [B, S_alloc, Hkv, D]
    v_cache: jax.Array,
    kv_pos: jax.Array,  # [B, S_alloc]
    pos: jax.Array,  # [B]
    slot: jax.Array,  # [B] ring slot to write
    window: jax.Array,
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode block. Returns (x, k_new, v_new) where k_new/v_new
    are the updated caches for this layer."""
    b = x.shape[0]
    h = _norm(params["ln1"], x, cfg)
    q, k, v = qkv_project(params["attn"], h, cfg)
    if cfg.use_rope:
        pos2d = pos[:, None]  # [B, 1]
        q = C.apply_rope(q, pos2d, cfg.rope_theta)
        k = C.apply_rope(k, pos2d, cfg.rope_theta)
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, slot].set(k[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v[:, 0])
    attn = decode_attention(q, k_cache, v_cache, kv_pos, pos, window)
    x = x + out_project(params["attn"], attn, cfg)
    h2 = _norm(params["ln2"], x, cfg)
    x = x + _ffn(params, h2, cfg)
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# LM: init / forward / loss / prefill / decode
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig) -> dict:
    k_emb, k_layers, k_pos = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: block_init(k, cfg))(layer_keys)
    params = {
        "embedding": C.embedding_init(k_emb, cfg),
        "layers": layers,
        "final_norm": _norm_init(cfg),
    }
    if not cfg.use_rope:
        params["pos_embed"] = C.embed_init(
            k_pos, (cfg.max_position, cfg.d_model), C.param_dtype(cfg)
        )
    return params


def _input_embeds(params, tokens, cfg, extra_embeds=None, position_offset=0):
    x = C.embed_tokens(params["embedding"], tokens, cfg)
    if extra_embeds is not None:
        # VLM stub: precomputed patch embeddings are prepended to the text.
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s) + position_offset
    if not cfg.use_rope:
        x = x + jnp.take(params["pos_embed"], positions, axis=0)[None]
    return x, positions


def forward_hidden(
    params: dict,
    tokens: jax.Array,  # [B, S_text]
    cfg: ModelConfig,
    *,
    extra_embeds: jax.Array | None = None,
    collect_kv: bool = False,
    remat: bool = True,
):
    """Returns final hidden states [B, S, d] (+ stacked per-layer KV)."""
    x, positions = _input_embeds(params, tokens, cfg, extra_embeds)
    windows = window_schedule(cfg)

    def body(x, xs):
        lp, win = xs
        x, kv = block_forward(lp, x, positions, win, cfg)
        return x, kv if collect_kv else None

    body_fn = jax.checkpoint(body) if remat else body
    x, kvs = jax.lax.scan(body_fn, x, (params["layers"], windows))
    x = _norm(params["final_norm"], x, cfg)
    return (x, kvs) if collect_kv else x


def loss_fn(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
) -> jax.Array:
    """Next-token cross entropy. batch: {tokens [B,S], labels [B,S], and
    optionally image_embeds/frame_embeds [B,S',d] for stub frontends}."""
    extra = batch.get("extra_embeds")
    x = forward_hidden(params, batch["tokens"], cfg, extra_embeds=extra)
    labels = batch["labels"]
    if extra is not None:
        # stub-frontend positions produce no LM loss
        pad = jnp.full(extra.shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return C.chunked_xent_loss(params["embedding"], x, labels, cfg)


# -- serving ---------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    s_alloc = cache_alloc_len(cfg, seq_len)
    dt = C.param_dtype(cfg)
    shape = (cfg.n_layers, batch, s_alloc, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "kv_pos": jnp.full((batch, s_alloc), -1, jnp.int32),
    }


def prefill(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    extra_embeds: jax.Array | None = None,
    max_len: int | None = None,
):
    """Full prompt pass. Returns (last-token logits [B, V], cache).

    ``max_len`` reserves decode headroom in the cache (defaults to the prompt
    length — the dry-run's "decode against a seq_len cache" semantics)."""
    x, (ks, vs) = forward_hidden(
        params, tokens, cfg, extra_embeds=extra_embeds, collect_kv=True
    )
    b, s = x.shape[0], x.shape[1]
    s_alloc = cache_alloc_len(cfg, max_len or s)
    if s_alloc < s:  # ring buffer: keep the last window, aligned to slots
        start = s - s_alloc  # ring slot of position p is p % s_alloc; since
        ks = ks[:, :, start:]  # s_alloc | window and we keep a contiguous
        vs = vs[:, :, start:]  # tail, slot order is a rotation — rebuild pos
        kept_pos = jnp.arange(start, s)
        slots = kept_pos % s_alloc
        inv = jnp.argsort(slots)
        ks = ks[:, :, inv]
        vs = vs[:, :, inv]
        kv_pos = jnp.broadcast_to(kept_pos[inv], (b, s_alloc))
    elif s_alloc > s:  # decode headroom
        pad = s_alloc - s
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.concatenate(
            [jnp.arange(s), jnp.full((pad,), -1, jnp.int32)]
        )
        kv_pos = jnp.broadcast_to(kv_pos, (b, s_alloc))
    else:
        kv_pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    cache = {
        "k": logical_constraint(ks, "layers", "batch", "seq_kv", "kv_heads", "d_head"),
        "v": logical_constraint(vs, "layers", "batch", "seq_kv", "kv_heads", "d_head"),
        "kv_pos": kv_pos,
    }
    logits = C.logits_last(params["embedding"], x[:, -1], cfg)
    return logits, cache


def decode_step(
    params: dict,
    cache: dict,
    tokens: jax.Array,  # [B]
    pos: jax.Array,  # [B] absolute position of the new token
    cfg: ModelConfig,
):
    """One token for every sequence in the batch. Returns (logits, cache)."""
    x, _ = _input_embeds(params, tokens[:, None], cfg, position_offset=0)
    if not cfg.use_rope:  # learned positions need the true offset
        x = C.embed_tokens(params["embedding"], tokens[:, None], cfg)
        x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None]
    s_alloc = cache["k"].shape[2]
    slot = pos % s_alloc
    kv_pos = cache["kv_pos"].at[jnp.arange(x.shape[0]), slot].set(pos)
    windows = window_schedule(cfg)

    def body(x, xs):
        lp, kc, vc, win = xs
        x, k_new, v_new = block_decode(
            lp, x, kc, vc, kv_pos, pos, slot, win, cfg
        )
        return x, (k_new, v_new)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], windows)
    )
    x = _norm(params["final_norm"], x, cfg)
    logits = C.logits_last(params["embedding"], x[:, 0], cfg)
    new_cache = {"k": ks, "v": vs, "kv_pos": kv_pos}
    return logits, new_cache
