"""Virtual host-device setup — the one place that may set
``--xla_force_host_platform_device_count``.

jax locks the device count at first backend initialization: once anything
calls ``jax.devices()`` (or runs a computation), ``XLA_FLAGS`` edits are
silently ignored. That makes "how many devices does the fleet see?" an
IMPORT-ORDER property — any entry point that imports jax before setting the
flag runs ``devices="auto"`` fleets on 1 device and never finds out. Every
entry point that wants multi-device CPU sharding must therefore call
:func:`force_host_device_count` BEFORE its first jax import (or at least
before the first backend touch); the helper is idempotent, never overrides
an explicit flag already in ``XLA_FLAGS``, and warns instead of lying when
it is called too late.

This module must stay import-light (no jax at module scope) so callers can
import it first, unconditionally.
"""

from __future__ import annotations

import os
import re
import sys
import warnings

_FLAG = "--xla_force_host_platform_device_count"


def host_device_flag() -> int | None:
    """The device count pinned in ``XLA_FLAGS``, or None if the flag is
    absent (jax will then expose 1 CPU device)."""
    m = re.search(rf"{_FLAG}=(\d+)", os.environ.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else None


def _backend_initialized() -> bool:
    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge

        return bool(xla_bridge.backends_are_initialized())
    except Exception:  # private API moved: assume the worst (too late)
        return True


def force_host_device_count(n: int | None = None) -> int:
    """Pin the CPU backend's virtual device count, exactly once.

    n: device count (default ``os.cpu_count()``). Returns the count that is
    actually in effect:

    * flag already in ``XLA_FLAGS`` (set by the user or an earlier call):
      that count wins — never overridden;
    * jax backend already initialized: too late, the flag would be ignored —
      warns and returns the live ``len(jax.devices())``;
    * otherwise appends the flag to ``XLA_FLAGS`` and returns ``n``.

    Virtual devices beyond the physical core count are legal (XLA threads
    oversubscribe) — useful for exercising multi-device code paths on small
    hosts, useless for speedup.
    """
    current = host_device_flag()
    if current is not None:
        return current
    if _backend_initialized():
        import jax

        live = len(jax.devices())
        if n is not None and n != live:
            warnings.warn(
                f"force_host_device_count({n}) called after jax backend "
                f"initialization — the flag would be ignored; continuing "
                f"with the live {live} device(s). Call this helper before "
                "the first jax import (see repro.utils.hostdev).",
                RuntimeWarning,
                stacklevel=2,
            )
        return live
    n = int(n) if n else (os.cpu_count() or 1)
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = f"{flags} {_FLAG}={n}".strip()
    return n
