"""Roofline terms for TPU v5e from a compiled dry-run artifact.

Hardware constants (per the assignment):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

All three terms are computed PER DEVICE (the SPMD module is per-device), so
    compute    = flops_dev / peak
    memory     = bytes_dev / hbm_bw
    collective = coll_bytes_dev / ici_bw
which equals the assignment's global form (global = dev × chips on both
numerator and denominator).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


@dataclasses.dataclass
class Roofline:
    flops_dev: float
    hbm_bytes_dev: float
    coll_bytes_dev: float
    n_chips: int
    model_flops_global: float = 0.0  # 6·N·D (train) or 2·N·D (inference)

    @property
    def compute_s(self) -> float:
        return self.flops_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much compiled compute is 'useful'."""
        total = self.flops_dev * self.n_chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU upper bound: useful flops / (time at the dominant
        term × peak). This is the score we hillclimb."""
        t = self.bound_s
        if t <= 0:
            return 0.0
        return (self.model_flops_global / self.n_chips) / (t * PEAK_FLOPS)

    def to_dict(self) -> dict:
        return {
            "flops_dev": self.flops_dev,
            "hbm_bytes_dev": self.hbm_bytes_dev,
            "coll_bytes_dev": self.coll_bytes_dev,
            "n_chips": self.n_chips,
            "model_flops_global": self.model_flops_global,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def memory_floor_bytes(
    kind: str,
    *,
    params_bytes_dev: float,
    cache_bytes_dev: float = 0.0,
    act_boundary_bytes_dev: float = 0.0,
) -> float:
    """Analytic lower bound on per-device HBM traffic for one step — the
    'ideal TPU' counterpart to the static-HLO estimate (which inherits some
    CPU-lowering copy noise; both are reported).

      decode : stream weights once + read the KV cache once
      prefill: stream weights + write cache + activation boundaries (remat)
      train  : weights bf16 r + grad f32 w + (m,v,master) f32 r/w
               (= 30 bytes/param) + 2× activation boundaries
    """
    if kind == "decode":
        return params_bytes_dev + cache_bytes_dev
    if kind == "prefill":
        return params_bytes_dev + cache_bytes_dev + act_boundary_bytes_dev
    per_param = 2 + 4 + 3 * 4 + 3 * 4  # bf16 read + f32 grad + opt r/w
    return params_bytes_dev / 2 * per_param + 2 * act_boundary_bytes_dev


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference-style passes (assignment's
    MODEL_FLOPS convention; attention flops excluded by convention)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
