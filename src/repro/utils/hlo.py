"""Trip-count-aware static analysis of compiled (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
but our models scan over layers / microbatches / KV chunks, so flops, bytes
and collective traffic would be undercounted by 10–200×. XLA:CPU annotates
every while with ``backend_config={"known_trip_count":{"n":...}}`` — we walk
the call graph (ENTRY → fusions/calls/whiles/conditionals) multiplying each
computation's cost by its execution count.

Per-computation costs:
  * flops              2 · |output| · contraction-size for every ``dot``
                       (elementwise flops ignored — documented; matmul
                       dominates every assigned arch)
  * hbm bytes          Σ (operand + result bytes) of every *top-level* op
                       except no-data-movement ops; fusion internals are
                       excluded (a fusion moves its boundary bytes once)
  * collective bytes   result-shape bytes of all-reduce(×2) / all-gather /
                       reduce-scatter(×group) / all-to-all / collective-
                       permute; ``-start`` counted, ``-done`` skipped

All values are PER DEVICE (the SPMD module is the per-device program).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
)

_NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
    # dtype converts fuse into their consumers on TPU (bf16 reads with fp32
    # MXU accumulation are native); XLA:CPU materializes them, which would
    # otherwise double-count the traffic of every mixed-precision matmul.
    "convert",
}

# ops traced through when resolving an operand's true stored size
_TRANSPARENT_OPS = {"convert", "bitcast"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# result type is either a tuple "( ... )" (may contain /*index=N*/ comments
# and layout parens like S(5)) or a single array type.
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*"
    r"(?P<type>\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>[a-z0-9\-]+)\((?P<rest>.*)$"
)
# header params may contain nested tuple-typed args: match greedily to "->".
_COMP_START_RE = re.compile(
    r"^(ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    op: str
    rest: str  # operands + attributes tail of the line


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0, "bytes": 0.0})
    )

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k]["count"] += v["count"] * mult
            self.coll_by_kind[k]["bytes"] += v["bytes"] * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Op]] = {}
        self.op_types: dict[str, str] = {}  # global symbol table
        self._passthrough: dict[str, str] = {}  # convert/bitcast → source
        self._parse(text)
        self._memo: dict[str, Cost] = {}

    def _parse(self, text: str):
        current: list[_Op] | None = None
        for line in text.splitlines():
            if current is None:
                m = _COMP_START_RE.match(line)
                if m and line.rstrip().endswith("{"):
                    current = []
                    self.computations[m.group("name")] = current
                continue
            if line.startswith("}"):
                current = None
                continue
            m = _OP_LINE_RE.match(line)
            if not m:
                continue
            op = _Op(m.group("name"), m.group("type"), m.group("op"), m.group("rest"))
            current.append(op)
            self.op_types[op.name] = op.type_str
            if op.op in _TRANSPARENT_OPS:
                src = _OPERAND_RE.search(op.rest)
                if src:
                    self._passthrough[op.name] = src.group(1)

    def _resolve_bytes(self, ref: str) -> int:
        """Stored size of a value, tracing through converts/bitcasts (their
        sources hold the real dtype that hits HBM)."""
        seen = 0
        while ref in self._passthrough and seen < 8:
            ref = self._passthrough[ref]
            seen += 1
        return _shape_bytes(self.op_types.get(ref, ""))

    # -- per-op costs -------------------------------------------------------

    def _dot_flops(self, op: _Op) -> float:
        out_elems = _shape_elems(op.type_str)
        lhs_match = _OPERAND_RE.search(op.rest)
        contraction = 1
        if lhs_match:
            lhs_type = self.op_types.get(lhs_match.group(1), "")
            dims = _shape_dims(lhs_type)
            cm = _CONTRACT_RE.search(op.rest)
            if cm and dims:
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        contraction *= dims[int(idx)]
        return 2.0 * out_elems * contraction

    def _op_bytes(self, op: _Op) -> float:
        if op.op in _NO_TRAFFIC_OPS:
            return 0.0
        result = float(_shape_bytes(op.type_str))
        # Sliced/in-place ops: XLA aliases the big operand (donation / while-
        # loop state), so traffic is the slice, not the whole buffer.
        if op.op == "dynamic-slice":
            return 2.0 * result  # read slice + write result
        if op.op in ("dynamic-update-slice", "scatter"):
            # operands: (target, update(s), indices...) — traffic ≈ 2·update
            refs = _OPERAND_RE.findall(op.rest.split(" metadata=")[0])
            if len(refs) >= 2:
                upd = self._resolve_bytes(refs[1])
                return 3.0 * upd  # read update, read+write slice region
            return result
        if op.op == "gather":
            refs = _OPERAND_RE.findall(op.rest.split(" metadata=")[0])
            idx = self._resolve_bytes(refs[1]) if len(refs) > 1 else 0
            return 2.0 * result + idx  # gathered rows read + result written
        if op.op in ("while", "conditional"):
            return 0.0  # loop/branch state aliases; bodies counted separately
        total = result
        # operands: look up types of referenced values defined in this module
        for ref in _OPERAND_RE.findall(op.rest.split(" metadata=")[0].split(", calls=")[0]):
            total += self._resolve_bytes(ref)
        return total

    def _fusion_bytes(self, op: _Op, comp_name: str) -> float:
        """Boundary traffic of a fusion, accounting for slicing inside it.

        A fusion whose parameter is only consumed by dynamic-slice/gather ops
        touches the slices, not the whole operand (XLA reads in-place); a
        fusion whose root is a dynamic-update-slice writes the update region
        and aliases the target buffer. Counting full operand/result sizes
        would overcount scanned-KV-cache models by ~100×.
        """
        comp = self.computations.get(comp_name, [])
        # map parameter index -> param op name; map op name -> op (in comp)
        param_names: dict[int, str] = {}
        by_name: dict[str, _Op] = {}
        for inner in comp:
            by_name[inner.name] = inner
            if inner.op == "parameter":
                m = re.match(r"(\d+)", inner.rest)
                if m:
                    param_names[int(m.group(1))] = inner.name
        consumers: dict[str, list[_Op]] = defaultdict(list)
        for inner in comp:
            if inner.op == "parameter":
                continue
            for ref in _OPERAND_RE.findall(inner.rest.split(" metadata=")[0]):
                consumers[ref].append(inner)

        def through_converts(name: str, down: bool) -> str:
            """Follow convert/bitcast/copy chains (producer- or consumer-ward)."""
            for _ in range(8):
                if down:
                    uses = consumers.get(name, [])
                    if len(uses) == 1 and uses[0].op in ("convert", "bitcast", "copy"):
                        name = uses[0].name
                        continue
                else:
                    o = by_name.get(name)
                    if o is not None and o.op in ("convert", "bitcast", "copy"):
                        refs = _OPERAND_RE.findall(o.rest.split(" metadata=")[0])
                        if refs:
                            name = refs[0]
                            continue
                break
            return name

        root = comp[-1] if comp else None
        eff_root = by_name.get(through_converts(root.name, down=False)) if root else None
        dus_target_params: set[str] = set()
        if eff_root is not None and eff_root.op == "dynamic-update-slice":
            refs = _OPERAND_RE.findall(eff_root.rest.split(" metadata=")[0])
            if refs:
                dus_target_params.add(through_converts(refs[0], down=False))

        operand_refs = _OPERAND_RE.findall(
            op.rest.split(" metadata=")[0].split(", kind=")[0]
        )
        total = 0.0
        for idx, ref in enumerate(operand_refs):
            pname = param_names.get(idx)
            if pname is None:
                total += self._resolve_bytes(ref)
                continue
            eff = through_converts(pname, down=True)
            if pname in dus_target_params or eff in dus_target_params:
                continue  # aliased in-place target
            uses = [u for u in consumers.get(eff, []) if u.op != "parameter"]
            if uses and all(u.op in ("dynamic-slice", "gather") for u in uses):
                total += sum(2.0 * _shape_bytes(u.type_str) for u in uses)
            else:
                total += self._resolve_bytes(ref)
        # result
        if eff_root is not None and eff_root.op == "dynamic-update-slice":
            refs = _OPERAND_RE.findall(eff_root.rest.split(" metadata=")[0])
            upd = self._resolve_bytes(refs[1]) if len(refs) > 1 else 0
            total += 2.0 * upd
        else:
            total += float(_shape_bytes(op.type_str))
        return total

    def _collective(self, op: _Op) -> tuple[str, float] | None:
        for kind in COLLECTIVES:
            if op.op == kind or op.op == kind + "-start":
                b = float(_shape_bytes(op.type_str))
                # Wire dtype: XLA:CPU promotes bf16 params to f32 before
                # FSDP gathers (its dots are f32-only); a TPU build gathers
                # the stored bf16. Scale to the convert-chain SOURCE dtype.
                refs = _OPERAND_RE.findall(op.rest.split(" metadata=")[0])
                if refs:
                    src = self._resolve_bytes(refs[0])
                    direct = _shape_bytes(self.op_types.get(refs[0], ""))
                    if src and direct and src < direct:
                        b *= src / direct
                if kind == "all-reduce":
                    b *= 2.0  # ring AR ≈ reduce-scatter + all-gather
                elif kind == "reduce-scatter":
                    m = _GROUPS_V2_RE.search(op.rest)
                    g = int(m.group(2)) if m else 0
                    if not g:
                        m = _GROUPS_RE.search(op.rest)
                        g = len(m.group(1).split(",")) if m else 1
                    b *= max(g, 1)
                return kind, b
            if op.op == kind + "-done":
                return kind, 0.0  # counted at -start
        return None

    # -- call graph ---------------------------------------------------------

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        total = Cost()
        self._memo[name] = total  # break cycles defensively
        for op in self.computations.get(name, ()):
            if op.op == "dot":
                total.flops += self._dot_flops(op)
                total.bytes += self._op_bytes(op)
            elif op.op == "fusion":
                m = _CALLED_RE.search(op.rest)
                if m:
                    inner = self.comp_cost(m.group(1))
                    total.flops += inner.flops  # dots inside fusions count
                    total.coll_bytes += inner.coll_bytes
                    total.bytes += self._fusion_bytes(op, m.group(1))
                else:
                    total.bytes += self._op_bytes(op)
            elif op.op == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _CALLED_RE.search(op.rest)
                cm = _COND_RE.search(op.rest)
                if bm:
                    total.add(self.comp_cost(bm.group(1)), trip)
                if cm:
                    total.add(self.comp_cost(cm.group(1)), trip)
            elif op.op == "conditional":
                m = _BRANCHES_RE.search(op.rest)
                if m:
                    branches = [
                        b.strip().lstrip("%") for b in m.group(1).split(",") if b.strip()
                    ]
                    costs = [self.comp_cost(b) for b in branches]
                    if costs:  # one branch executes; take the max
                        worst = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(worst)
                total.bytes += self._op_bytes(op)
            elif op.op == "call":
                m = _CALLED_RE.search(op.rest)
                if m:
                    total.add(self.comp_cost(m.group(1)))
            else:
                coll = self._collective(op)
                if coll is not None:
                    kind, b = coll
                    if b > 0:
                        total.coll_bytes += b
                        total.coll_by_kind[kind]["count"] += 1
                        total.coll_by_kind[kind]["bytes"] += b
                    continue
                total.bytes += self._op_bytes(op)
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        # fusions/while bodies are reached via the call graph from ENTRY; the
        # ENTRY computation is the one referenced nowhere else — XLA puts it
        # last and marks it in the header, but we kept only names. Heuristic:
        # the computation named like "main" or the largest one not called.
        called = set()
        for ops in self.computations.values():
            for op in ops:
                for pat in (_CALLED_RE, _COND_RE):
                    m = pat.search(op.rest)
                    if m:
                        called.add(m.group(1))
                m = _BRANCHES_RE.search(op.rest)
                if m:
                    called.update(
                        b.strip().lstrip("%") for b in m.group(1).split(",")
                    )
        roots = [n for n in self.computations if n not in called]
        main = [n for n in roots if "main" in n]
        entry = main[0] if main else (roots[0] if roots else "")
        return self.comp_cost(entry)


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jaxlib versions.

    Older jaxlib returns a flat ``{property: value}`` dict; newer jaxlib
    returns a list with one such dict per executable. Always hand back a
    single dict (empty when XLA reports nothing) so callers can ``.get``.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def analyze_hlo(text: str) -> dict:
    mod = HloModule(text)
    cost = mod.entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.coll_bytes,
        "collectives": {k: dict(v) for k, v in cost.coll_by_kind.items()},
        "n_computations": len(mod.computations),
    }


def collective_stats(hlo_text: str) -> dict:
    """Back-compat summary: {kind: {count, bytes}, total_bytes} (trip-aware)."""
    res = analyze_hlo(hlo_text)
    out = dict(res["collectives"])
    out["total_bytes"] = res["collective_bytes"]
    return out


# ---------------------------------------------------------------------------
# Attribution CLI for §Perf work:
#   PYTHONPATH=src python -m repro.utils.hlo <file.hlo.txt> [--top N]
# ---------------------------------------------------------------------------

def attribute(text: str) -> tuple[dict, dict]:
    """(bytes by op kind, collective bytes by (kind, shape)) with trip counts."""
    mod = HloModule(text)
    mults: dict[str, float] = defaultdict(float)

    def walk(name: str, m: float):
        mults[name] += m
        for op in mod.computations.get(name, ()):
            if op.op == "while":
                t = _TRIP_RE.search(op.rest)
                trip = int(t.group(1)) if t else 1
                for pat in (_CALLED_RE, _COND_RE):
                    mm = pat.search(op.rest)
                    if mm:
                        walk(mm.group(1), m * trip)
            elif op.op in ("fusion", "call"):
                mm = _CALLED_RE.search(op.rest)
                if mm:
                    walk(mm.group(1), m)

    called = set()
    for ops in mod.computations.values():
        for op in ops:
            for pat in (_CALLED_RE, _COND_RE):
                m = pat.search(op.rest)
                if m:
                    called.add(m.group(1))
    roots = [n for n in mod.computations if n not in called]
    entry = next((n for n in roots if "main" in n), roots[0] if roots else "")
    walk(entry, 1.0)

    by_kind: dict[str, float] = defaultdict(float)
    coll_detail: dict[str, float] = defaultdict(float)
    for name, ops in mod.computations.items():
        m = mults.get(name, 0.0)
        if not m:
            continue
        for op in ops:
            coll = mod._collective(op)
            if coll:
                kind, b = coll
                if b:
                    coll_detail[f"{kind} {op.type_str[:48]}"] += b * m
                continue
            if op.op == "fusion":
                cm = _CALLED_RE.search(op.rest)
                if cm:
                    by_kind["fusion"] += mod._fusion_bytes(op, cm.group(1)) * m
                    continue
            by_kind[op.op] += mod._op_bytes(op) * m
    return dict(by_kind), dict(coll_detail)


def main(argv=None):
    import argparse
    import json as _json

    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args(argv)
    text = open(args.path).read()
    print(_json.dumps(analyze_hlo(text), indent=2, default=str))
    by_kind, coll = attribute(text)
    print("\n-- HBM bytes by op kind --")
    for k, v in sorted(by_kind.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"{k:28s} {v/1e9:10.2f} GB")
    print("\n-- collective bytes by op/shape --")
    for k, v in sorted(coll.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"{k:80s} {v/1e9:10.2f} GB")


if __name__ == "__main__":
    main()
