"""Assemble the dry-run/roofline markdown tables from reports/dryrun/*.json.

    PYTHONPATH=src python -m repro.utils.report            # print tables
    PYTHONPATH=src python -m repro.utils.report --csv      # machine-readable
"""

from __future__ import annotations

import argparse
import json
import pathlib

CELL_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load_cells(directory="reports/dryrun"):
    cells = {}
    for f in pathlib.Path(directory).glob("*.json"):
        d = json.loads(f.read_text())
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def _fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}µs"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def roofline_rows(cells, mesh="single"):
    rows = []
    for (arch, shape, m), d in sorted(cells.items()):
        if m != mesh:
            continue
        if d.get("skipped"):
            rows.append({
                "arch": arch, "shape": shape, "skipped": True,
            })
            continue
        if "error" in d:
            rows.append({"arch": arch, "shape": shape, "error": True})
            continue
        r = d["roofline"]
        mem = d.get("memory", {})
        rows.append({
            "arch": arch,
            "shape": shape,
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "floor_s": r.get("memory_floor_s", 0),
            "coll_s": r["collective_s"],
            "dominant": r["dominant"],
            "useful": r["useful_flops_ratio"],
            "roofline_frac": r["roofline_fraction"],
            "hbm_gb": mem.get("per_device_hbm_bytes", 0) / 2**30,
        })
    return rows


def markdown_table(rows) -> str:
    hdr = (
        "| arch | shape | compute | memory (floor) | collective | dominant "
        "| useful-FLOPs | roofline-frac | HBM GB/dev |"
    )
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                f"(full attention @500k) | — | — | — |"
            )
            continue
        if r.get("error"):
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} "
            f"| {_fmt_s(r['memory_s'])} ({_fmt_s(r['floor_s'])}) "
            f"| {_fmt_s(r['coll_s'])} | {r['dominant']} "
            f"| {r['useful']:.3f} | {r['roofline_frac']:.3f} "
            f"| {r['hbm_gb']:.2f} |"
        )
    return "\n".join(lines)


def dryrun_summary(cells) -> str:
    ok = sum(
        1 for d in cells.values() if not d.get("skipped") and "error" not in d
    )
    skipped = sum(1 for d in cells.values() if d.get("skipped"))
    failed = sum(1 for d in cells.values() if "error" in d)
    lines = [
        f"cells: {len(cells)} — compiled OK: {ok}, skipped: {skipped}, failed: {failed}",
    ]
    for mesh in ("single", "multi"):
        sub = [d for (a, s, m), d in cells.items() if m == mesh and "roofline" in d]
        if not sub:
            continue
        lines.append(
            f"  {mesh}: {len(sub)} compiled, "
            f"median compile {sorted(d['compile_s'] for d in sub)[len(sub)//2]:.1f}s"
        )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    cells = load_cells()
    print(dryrun_summary(cells))
    rows = roofline_rows(cells, args.mesh)
    if args.csv:
        import csv
        import sys

        w = csv.DictWriter(sys.stdout, fieldnames=list(rows[0].keys()))
        w.writeheader()
        for r in rows:
            w.writerow(r)
    else:
        print(markdown_table(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
