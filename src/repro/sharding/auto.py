"""Automatic parameter/state sharding assignment (FSDP+TP hybrid).

Weight placement does not change numerics — any sharding is *correct* (XLA
inserts the collectives) — so instead of a hand table per arch we assign
shardings greedily per tensor:

  1. shard the largest dim divisible by |model| over ``model``  (TP/EP)
  2. shard the largest remaining dim divisible by |data| over ``data`` (FSDP)
  3. leave everything else replicated

Leaves under a stacked-layer key ("layers", "encoder", "decoder", "blocks")
skip their leading (layer) dim. This handles every assigned arch — including
the awkward ones (56 or 25 heads vs a 16-way model axis) — without per-arch
exceptions; the roofline/§Perf pass then *tunes* placements where it matters.

Optimizer state (m/v) and the fp32 master copy inherit the param sharding.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

STACKED_KEYS = ("layers", "encoder", "decoder", "blocks")

# Semantic TP preferences: shard the dim that MATCHES the activation
# sharding (heads for attention, experts/ff for MoE/MLP), so contractions
# stay local instead of XLA re-gathering the whole weight per layer
# (§Perf cell 3: wo sharded by d_model cost 7.9 GB/step of all-gathers).

def _preferred_tp_dim(key: str, rank: int) -> int | None:
    if key in ("wq", "wk", "wv"):
        return rank - 2  # [d, H, dh] → heads
    if key == "wo":
        return 0  # attn [H, dh, d] / mlp [f, d] → H / f (moe [E,f,d]: E→greedy)
    if key in ("wi_gate", "wi_up", "wi"):
        return rank - 1  # [.., d, f] → f
    return None


def _spec_for_shape(
    shape: tuple[int, ...],
    mesh: Mesh,
    *,
    skip_leading: bool = False,
    axes: tuple[str, ...] = ("model", "data"),
    preferred_model_dim: int | None = None,
) -> P:
    axes_avail = [a for a in axes if a in mesh.axis_names]
    parts: list[Any] = [None] * len(shape)
    start = 1 if (skip_leading and len(shape) > 1) else 0
    order = sorted(
        range(start, len(shape)), key=lambda i: shape[i], reverse=True
    )
    if preferred_model_dim is not None:
        pd = preferred_model_dim + start
        if pd < len(shape):
            order = [pd] + [i for i in order if i != pd]
    for mesh_axis in axes_avail:
        size = mesh.shape[mesh_axis]
        for i in order:
            if parts[i] is None and shape[i] % size == 0 and shape[i] >= size:
                parts[i] = mesh_axis
                break
        # only the model axis gets the semantic preference
        if preferred_model_dim is not None and mesh_axis == "model":
            order = sorted(
                (i for i in range(start, len(shape))),
                key=lambda i: shape[i],
                reverse=True,
            )
    return P(*parts)


def _is_stacked(path) -> bool:
    for entry in path:
        key = getattr(entry, "key", None) or getattr(entry, "name", None)
        if key in STACKED_KEYS:
            return True
    return False


def auto_shardings(tree: Any, mesh: Mesh, *, mode: str = "auto") -> Any:
    """Param-tree → NamedSharding-tree (same structure).

    mode="auto": FSDP(data) + TP(model) hybrid — best for training, where
    per-microbatch weight gathers amortize across the batch.
    mode="tp":   TP(model) only, no data-axis sharding — the right placement
    for decode/serving, where weights stream once per token and an FSDP
    gather would push the whole model over ICI every step (§Perf cell 3).
    """

    def assign(path, leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if any(k in ("pos_embed", "embed") for k in keys):
            # row-gathered tables (token/pos embeddings): shard ONLY the row
            # dim (vocab/position) — sharding the feature dim of a gather
            # operand trips the SPMD partitioner ("slice dim size > dynamic
            # slice dimension"); replicate when rows don't divide.
            size = mesh.shape.get("model", 1)
            axis = "model" if (shape[0] % size == 0 and size > 1) else None
            return NamedSharding(mesh, P(axis, *([None] * (len(shape) - 1))))
        stacked = _is_stacked(path)
        last_key = keys[-1] if keys else ""
        rank = len(shape) - (1 if stacked and len(shape) > 1 else 0)
        spec = _spec_for_shape(
            tuple(shape),
            mesh,
            skip_leading=stacked,
            axes=("model",) if mode == "tp" else ("model", "data"),
            preferred_model_dim=_preferred_tp_dim(last_key, rank),
        )
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, tree)


def batch_shardings(tree: Any, mesh: Mesh) -> Any:
    """Data-batch tree → shard dim0 over (pod, data)."""
    bd = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def assign(leaf):
        shape = getattr(leaf, "shape", ())
        if not shape:
            return NamedSharding(mesh, P())
        size = int(np.prod([mesh.shape[a] for a in bd])) if bd else 1
        if shape[0] % max(size, 1) == 0 and size > 1:
            return NamedSharding(mesh, P(bd))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(assign, tree)


def cache_shardings(tree: Any, mesh: Mesh, *, seq_axis: str = "model") -> Any:
    """KV-cache tree sharding.

    Layout conventions (see models/*.py init_cache):
      rank-5 [L, B, S, H, D] → batch over (pod,data), S over ``seq_axis``
      rank-4 [L, B, *, *]    → batch over (pod,data)          (ssm states)
      rank-2/3 [B, ...]      → batch over (pod,data)
    Falls back to replication when not divisible.
    """
    bd = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = int(np.prod([mesh.shape[a] for a in bd])) if bd else 1
    ssize = mesh.shape[seq_axis] if seq_axis in mesh.axis_names else 1

    def assign(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if len(shape) >= 2 and shape[0] == 0:
            return NamedSharding(mesh, P())
        parts: list[Any] = [None] * len(shape)
        if len(shape) == 5:  # [L, B, S, H, D]
            if bd and shape[1] % bsize == 0:
                parts[1] = bd
            if ssize > 1 and shape[2] % ssize == 0:
                parts[2] = seq_axis
        elif len(shape) >= 2 and bd:
            # first dim that matches a batch size
            for i in (1, 0):
                if i < len(shape) and shape[i] % bsize == 0 and shape[i] >= bsize:
                    parts[i] = bd
                    break
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map(assign, tree)
