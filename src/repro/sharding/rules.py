"""Logical-axis sharding rules (MaxText-style) + a context for applying them.

Models annotate activations/params with *logical* axis names; a rules table
maps logical names to mesh axes. Outside a rules context every annotation is a
no-op, so the same model code runs in single-device smoke tests and in the
512-device dry-run unchanged.

Mesh axes (see launch/mesh.py):
    pod    across pods (multi-pod DP)
    data   FSDP / batch
    model  TP / EP / SP
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicated)
DEFAULT_RULES: dict[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_kv": "model",        # KV-sequence sharding for decode (SP/flash-decoding)
    "heads": "model",
    "kv_heads": "model",
    "d_model": None,
    "d_ff": "model",
    "vocab": "model",
    # parameters (FSDP over data, TP over model)
    "p_d_model": "data",
    "p_heads": "model",
    "p_kv_heads": "model",
    "p_d_ff": "model",
    "p_vocab": "model",
    "p_experts": None,        # overridden to "model" when divisible (EP)
    "layers": None,
    # never sharded
    "d_head": None,
    "state": None,
    "window": None,
}


class _RulesContext(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict[str, object] = {}


_CTX = _RulesContext()


@contextlib.contextmanager
def use_sharding_rules(mesh: Mesh, rules: Optional[dict] = None, /, **overrides):
    """Activate logical-axis sharding for all ``logical_constraint`` calls."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    merged.update(overrides)
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, merged
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def resolve_spec(names: Sequence[Optional[str]]) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    rules = _CTX.rules or DEFAULT_RULES
    mesh = _CTX.mesh
    parts, used = [], set()
    for name in names:
        axis = rules.get(name) if name is not None else None
        # Drop mesh axes that do not exist on the active mesh (e.g. "pod" on
        # the single-pod mesh) and axes already consumed by an earlier dim.
        if axis is not None and mesh is not None:
            if isinstance(axis, (tuple, list)):
                axis = tuple(a for a in axis if a in mesh.axis_names and a not in used)
                axis = axis if axis else None
            elif axis not in mesh.axis_names or axis in used:
                axis = None
        if axis is not None:
            used.update(axis if isinstance(axis, tuple) else (axis,))
        parts.append(axis)
    return P(*parts)


def logical_constraint(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; identity w/o active mesh.

    A logical axis is silently dropped (replicated) when the dimension size
    does not divide the mesh axis size — this keeps reduced smoke configs and
    odd head counts (e.g. hymba's 25 heads) compiling, at the cost of
    replication, which the dry-run memory analysis then makes visible.
    """
    mesh = _CTX.mesh
    if mesh is None:
        return x
    assert len(names) == x.ndim, f"rank mismatch: {names} vs {x.shape}"
    spec = resolve_spec(list(names))
    # Divisibility check per dim.
    fixed = []
    for dim, axis in zip(x.shape, spec):
        if axis is None:
            fixed.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(axis if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


def named_sharding(mesh: Mesh, *names: Optional[str]) -> NamedSharding:
    """Build a NamedSharding for in_shardings/out_shardings declarations."""
    rules = _CTX.rules or DEFAULT_RULES
    parts, used = [], set()
    for name in names:
        axis = rules.get(name) if name is not None else None
        if axis is not None:
            if isinstance(axis, (tuple, list)):
                axis = tuple(a for a in axis if a in mesh.axis_names and a not in used)
                axis = axis or None
            elif axis not in mesh.axis_names or axis in used:
                axis = None
        if axis is not None:
            used.update(axis if isinstance(axis, tuple) else (axis,))
        parts.append(axis)
    return NamedSharding(mesh, P(*parts))
