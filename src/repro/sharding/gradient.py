"""Gradient compression for cross-pod data parallelism.

At 2+ pods the gradient all-reduce over the ``pod`` axis crosses the slow
inter-pod links — compressing it is the classic distributed-optimization
lever. Two tools:

* ``compress_tree`` / ``decompress_tree`` — stochastic-rounding int8 (or
  bf16) tree codec with per-leaf scales and an ERROR-FEEDBACK residual
  carried in the optimizer state, so compression noise doesn't bias the
  update (Seide et al. 1-bit SGD lineage).
* ``compressed_psum`` — a shard_map-compatible mean-reduce that quantizes
  before the collective: int8 over the wire = 4× less inter-pod traffic.

The train loop applies error feedback OUTSIDE the collective:
    g_eff = g + residual
    q     = quantize(g_eff);  residual = g_eff - dequantize(q)
    g_out = psum(dequantize(q)) / n
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp


def _quant_int8(x: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    y = x / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(tree: Any, rng, *, mode: str = "int8"):
    """tree -> (payload tree, meta). mode: int8 | bf16 | none."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if mode == "none":
        return tree, None
    if mode == "bf16":
        payload = [l.astype(jnp.bfloat16) for l in leaves]
        return jax.tree_util.tree_unflatten(treedef, payload), None
    keys = jax.random.split(rng, len(leaves))
    qs, scales = [], []
    for l, k in zip(leaves, keys):
        q, s = _quant_int8(l.astype(jnp.float32), k)
        qs.append(q)
        scales.append(s)
    return (
        jax.tree_util.tree_unflatten(treedef, qs),
        jax.tree_util.tree_unflatten(treedef, scales),
    )


def decompress_tree(payload: Any, meta: Any, like: Any):
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    p_leaves = treedef.flatten_up_to(payload)
    if meta is None:  # bf16 / none
        out = [p.astype(l.dtype) for p, l in zip(p_leaves, like_leaves)]
        return jax.tree_util.tree_unflatten(treedef, out)
    m_leaves = treedef.flatten_up_to(meta)
    out = [
        _dequant_int8(p, s, l.dtype)
        for p, s, l in zip(p_leaves, m_leaves, like_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def error_feedback_step(grads: Any, residual: Any, rng, *, mode: str = "int8"):
    """(grads, residual) -> (decompressed-effective grads, new residual).

    The returned grads are exactly what the optimizer should consume after
    the (possibly lossy) wire format; the residual carries what was lost.
    """
    if mode == "none":
        return grads, residual
    eff = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual
    )
    payload, meta = compress_tree(eff, rng, mode=mode)
    restored = decompress_tree(payload, meta, eff)
    new_residual = jax.tree_util.tree_map(
        lambda e, d: e - d.astype(jnp.float32), eff, restored
    )
    return restored, new_residual


def init_residual(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compressed_psum(x: jax.Array, axis: str, rng, *, mode: str = "int8"):
    """Mean over mesh axis `axis` with int8 wire format (use inside
    shard_map). Each participant quantizes its contribution; scales are
    all-gathered (tiny) and the int8 payloads all-reduced bucket-wise."""
    n = jax.lax.psum(1, axis)
    if mode == "none":
        return jax.lax.psum(x, axis) / n
    q, scale = _quant_int8(x.astype(jnp.float32), rng)
    # contributions have different scales: reduce in a common scale
    s_max = jax.lax.pmax(scale, axis)
    rescaled = (q.astype(jnp.float32) * (scale / s_max)).astype(jnp.float32)
    total = jax.lax.psum(rescaled, axis)
    return (total * s_max / n).astype(x.dtype)
