"""Transformer decode on the Wolf-KV paged cache.

Shares parameters with models/transformer (same init_params tree), but the
per-layer KV lives in the global block pool and attention goes through the
paged-attention Pallas kernel, consuming Wolf-KV's block tables + validity
masks. This is the device data path of the serving engine; the host control
plane is kvcache/manager.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import common as C
from repro.models.transformer import _norm, _ffn


def init_pools(cfg: ModelConfig, n_blocks: int, page: int) -> dict:
    dt = C.param_dtype(cfg)
    shape = (cfg.n_layers, n_blocks, page, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


@functools.partial(jax.jit, static_argnames=("cfg",))
def paged_decode_step(
    params: dict,
    cfg: ModelConfig,
    pools: dict,  # {"k","v": [L, N, P, Hkv, D]}
    tables: jax.Array,  # [B, M] int32
    slot_valid: jax.Array,  # [B, M, P] int8
    lengths: jax.Array,  # [B] cache length INCLUDING the new token
    write_blk: jax.Array,  # [B] block for the new token's KV
    write_slot: jax.Array,  # [B]
    tokens: jax.Array,  # [B]
    pos: jax.Array,  # [B] absolute positions (for RoPE)
):
    """One decode token per sequence. Returns (logits [B, V], pools)."""
    b = tokens.shape[0]
    x = C.embed_tokens(params["embedding"], tokens[:, None], cfg)
    bidx = jnp.arange(b)

    def body(x, xs):
        lp, k_pool, v_pool = xs
        h = _norm(lp["ln1"], x, cfg)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        if cfg.use_rope:
            q = C.apply_rope(q, pos[:, None], cfg.rope_theta)
            k = C.apply_rope(k, pos[:, None], cfg.rope_theta)
        k_pool = k_pool.at[write_blk, write_slot].set(k[:, 0])
        v_pool = v_pool.at[write_blk, write_slot].set(v[:, 0])
        attn = paged_attention(
            q[:, 0], k_pool, v_pool, tables, lengths, slot_valid
        )
        x = x + jnp.einsum("bhk,hkd->bd", attn, lp["attn"]["wo"])[:, None]
        h2 = _norm(lp["ln2"], x, cfg)
        x = x + _ffn(lp, h2, cfg)
        return x, (k_pool, v_pool)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], pools["k"], pools["v"])
    )
    x = _norm(params["final_norm"], x, cfg)
    logits = C.logits_last(params["embedding"], x[:, 0], cfg)
    return logits, {"k": ks, "v": vs}


@functools.partial(jax.jit, static_argnames=("cfg",))
def paged_prefill(
    params: dict,
    cfg: ModelConfig,
    pools: dict,
    tokens: jax.Array,  # [B, S]
    write_blk: jax.Array,  # [B, S] per-token destination block
    write_slot: jax.Array,  # [B, S]
):
    """Prompt pass that writes KV straight into the paged pool."""
    from repro.models.attention import chunked_attention
    from repro.models.transformer import window_schedule

    b, s = tokens.shape
    x, positions = (
        C.embed_tokens(params["embedding"], tokens, cfg),
        jnp.arange(s),
    )
    windows = window_schedule(cfg)
    bflat = write_blk.reshape(-1)
    sflat = write_slot.reshape(-1)

    def body(x, xs):
        lp, k_pool, v_pool, win = xs
        h = _norm(lp["ln1"], x, cfg)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        if cfg.use_rope:
            q = C.apply_rope(q, positions, cfg.rope_theta)
            k = C.apply_rope(k, positions, cfg.rope_theta)
        attn = chunked_attention(q, k, v, win, causal=True)
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["attn"]["wo"])
        h2 = _norm(lp["ln2"], x, cfg)
        x = x + _ffn(lp, h2, cfg)
        k_pool = k_pool.at[bflat, sflat].set(k.reshape(b * s, *k.shape[2:]))
        v_pool = v_pool.at[bflat, sflat].set(v.reshape(b * s, *v.shape[2:]))
        return x, (k_pool, v_pool)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], pools["k"], pools["v"], windows)
    )
    x = _norm(params["final_norm"], x, cfg)
    logits = C.logits_last(params["embedding"], x[:, -1], cfg)
    return logits, {"k": ks, "v": vs}


def apply_moves(pools: dict, moves) -> dict:
    """Execute the manager's compaction move list on-device (gc_compact)."""
    import numpy as np

    from repro.kernels.gc_compact.ops import gc_compact

    if not moves:
        return pools
    mv = np.asarray(moves, np.int32)
    sb, ss, db, ds = (jnp.asarray(mv[:, i]) for i in range(4))

    def per_layer(kv):
        k, v = kv
        return gc_compact(k, v, sb, ss, db, ds)

    k_new, v_new = jax.vmap(per_layer)((pools["k"], pools["v"]))
    return {"k": k_new, "v": v_new}
