"""Serving engine: continuous batching over the Wolf-KV paged cache.

Request model:
  * ``policy="append"``  — standard decode; blocks die only when the request
    finishes (cold churn).
  * ``policy="h2o:R"``   — heavy-hitter-style eviction: every new token
    evicts one of the oldest R% cache entries at random (hot churn — the
    serving analogue of the paper's hot pages).
  * ``policy="window:W"``— sliding-window: tokens beyond W evicted in order
    (prefix pages die whole — cheap reclamation).

Each policy class is a Wolf-KV temperature group. The engine demonstrates
the full loop: prefill → decode (paged-attention kernel) → eviction →
compaction move-lists executed by the gc_compact kernel. WA is reported by
the manager. Production posture: the same control plane scales to one
manager per model replica; block tables ride along with the batch.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kvcache.manager import WolfKVManager
from repro.models.transformer import init_params
from repro.serving.paged_model import (
    apply_moves,
    init_pools,
    paged_decode_step,
    paged_prefill,
)

POLICIES = ("append", "h2o", "window")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32 tokens
    max_new: int
    policy: str = "append"  # append | h2o:<rate%> | window:<W>
    out: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def policy_kind(self) -> str:
        return self.policy.split(":")[0]

    @property
    def policy_arg(self) -> int:
        parts = self.policy.split(":")
        return int(parts[1]) if len(parts) > 1 else 0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        n_blocks: int = 256,
        page: int = 16,
        max_pages_per_seq: int = 32,
        max_batch: int = 8,
        groups: tuple[str, ...] = ("append", "h2o", "window"),
        adaptive: bool = True,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.page = page
        self.max_pages = max_pages_per_seq
        self.max_batch = max_batch
        self.group_of_policy = {k: i for i, k in enumerate(groups)}
        self.params = init_params(jax.random.PRNGKey(seed), cfg)
        self.pools = init_pools(cfg, n_blocks, page)
        self.manager = WolfKVManager(
            n_blocks, page, len(groups), adaptive=adaptive
        )
        self.queue: deque[Request] = deque()
        self.running: list[Request] = []
        self.rng = np.random.default_rng(seed)
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        while self.queue and len(self.running) < self.max_batch:
            req = self.queue.popleft()
            g = self.group_of_policy[req.policy_kind]
            self.manager.add_sequence(req.rid, g)
            # prefill: reserve slots for every prompt token, then one pass
            wb = np.zeros(len(req.prompt), np.int32)
            ws = np.zeros(len(req.prompt), np.int32)
            for i in range(len(req.prompt)):
                wb[i], ws[i] = self.manager.append_token(req.rid)
            self.pools = apply_moves(self.pools, self.manager.drain_moves())
            logits, self.pools = paged_prefill(
                self.params, self.cfg, self.pools,
                jnp.asarray(req.prompt[None], jnp.int32),
                jnp.asarray(wb[None]), jnp.asarray(ws[None]),
            )
            req.out.append(int(jnp.argmax(logits[0])))
            self.running.append(req)

    def _evict(self, req: Request):
        mgr, sid = self.manager, req.rid
        seq = mgr.seqs[sid]
        if req.policy_kind == "window":
            w = max(req.policy_arg, self.page)
            # evict everything below cache_len - w
            lo = 0
            hi = seq.cache_len - w
            for ci in range(hi):
                if ci < len(seq.valid) and seq.valid[ci]:
                    mgr.evict_token(sid, ci)
        elif req.policy_kind == "h2o":
            rate = req.policy_arg or 50
            # one-in, one-out beyond a warmup, from the oldest `rate`% alive
            alive = np.flatnonzero(seq.valid[: seq.cache_len])
            if len(alive) > 4 * self.page:
                k = max(1, int(len(alive) * rate / 100))
                victim = int(self.rng.choice(alive[:k]))
                mgr.evict_token(sid, victim)

    def step(self) -> dict:
        """One engine iteration: admit, decode one token each, evict, GC."""
        self._admit()
        if not self.running:
            return {"running": 0, "wa": self.manager.write_amplification}
        b = len(self.running)
        tokens = np.zeros(b, np.int32)
        wb = np.zeros(b, np.int32)
        ws = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        for i, req in enumerate(self.running):
            tokens[i] = req.out[-1]
            pos[i] = self.manager.cache_len(req.rid)
            wb[i], ws[i] = self.manager.append_token(req.rid)
        self.pools = apply_moves(self.pools, self.manager.drain_moves())
        tables = np.stack(
            [self.manager.block_table(r.rid, self.max_pages) for r in self.running]
        )
        valid = np.stack(
            [self.manager.slot_valid(r.rid, self.max_pages) for r in self.running]
        )
        lengths = np.asarray(
            [self.manager.cache_len(r.rid) for r in self.running], np.int32
        )
        logits, self.pools = paged_decode_step(
            self.params, self.cfg, self.pools,
            jnp.asarray(tables), jnp.asarray(valid, jnp.int8),
            jnp.asarray(lengths), jnp.asarray(wb), jnp.asarray(ws),
            jnp.asarray(tokens), jnp.asarray(pos),
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        still = []
        for i, req in enumerate(self.running):
            req.out.append(int(nxt[i]))
            self._evict(req)
            if len(req.out) >= req.max_new:
                req.done = True
                self.manager.finish_sequence(req.rid)
            else:
                still.append(req)
        self.running = still
        self.pools = apply_moves(self.pools, self.manager.drain_moves())
        self.steps += 1
        return {
            "running": len(self.running),
            "wa": self.manager.write_amplification,
            "free_blocks": len(self.manager.free),
        }

    def run_until_drained(self, max_steps: int = 10_000) -> dict:
        for _ in range(max_steps):
            info = self.step()
            if not self.running and not self.queue:
                break
        return {
            "steps": self.steps,
            "wa": self.manager.write_amplification,
            "appended": self.manager.appended,
            "copied": self.manager.copied,
        }
