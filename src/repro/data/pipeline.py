"""Deterministic synthetic token pipeline, sharded per host.

Production posture: each host materializes only its shard of the global
batch (``shard_id``/``num_shards``), derived deterministically from
(seed, step) — so restarts resume mid-epoch exactly, elastic re-sharding
re-partitions the same global stream, and no host ever reads another's data.

The sequences follow a learnable affine recurrence
    x_{t+1} = (a·x_t + b) mod vocab
with stream-global (a, b) and per-sequence random x_0: the transition is a
fixed function of the current token, so a real LM drives loss toward zero by
learning it — examples/train_lm.py demonstrates convergence.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards


class TokenStream:
    """Stateless: batch(step) is a pure function — restart-safe."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        b = cfg.shard_batch
        # per-(step, shard, row) independent RNG
        seeds = (
            np.uint64(cfg.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(num := cfg.num_shards)
            + np.uint64(cfg.shard_id)
        )
        rng = np.random.default_rng(int(seeds))
        grng = np.random.default_rng(cfg.seed)  # stream-global transition
        a = np.int64(grng.integers(1, 64) * 2 + 1)
        c = np.int64(grng.integers(0, cfg.vocab))
        x0 = rng.integers(0, cfg.vocab, size=(b, 1), dtype=np.int64)
        t = np.arange(cfg.seq_len + 1, dtype=np.int64)[None, :]
        seq = x0
        rows = [x0]
        for _ in range(cfg.seq_len):
            seq = (a * seq + c) % cfg.vocab
            rows.append(seq)
        tokens = np.concatenate(rows, axis=1)  # [b, seq_len + 1]
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
