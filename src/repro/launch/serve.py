"""Serving launcher: batched requests over the Wolf-KV paged cache.

    PYTHONPATH=src python -m repro.launch.serve --requests 12 --max-new 24
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.models.registry import ALL_ARCHS, get_config, smoke_config
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--blocks", type=int, default=256)
    ap.add_argument("--page", type=int, default=16)
    ap.add_argument("--static", action="store_true", help="disable Wolf adaptivity")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(get_config(args.arch))
    eng = ServingEngine(
        cfg,
        n_blocks=args.blocks,
        page=args.page,
        max_pages_per_seq=64,
        max_batch=8,
        adaptive=not args.static,
        seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    policies = ["append", "h2o:50", "window:32"]
    for rid in range(args.requests):
        eng.submit(
            Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                max_new=args.max_new,
                policy=policies[rid % len(policies)],
            )
        )
    while eng.running or eng.queue:
        info = eng.step()
        if eng.steps % 8 == 0:
            print(
                f"step {eng.steps:4d}  running {info['running']}  "
                f"WA {info['wa']:.3f}  free blocks {info.get('free_blocks', '-')}"
            )
    m = eng.manager
    print(
        f"drained: steps={eng.steps} appended={m.appended} copied={m.copied} "
        f"WA={m.write_amplification:.3f}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
