"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first backend
init, and only launch/dryrun.py is allowed to force 512 host devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips when multi_pod.

    The dry-run process exposes 512 placeholder devices; the single-pod mesh
    uses the first 256 of them."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests use small ones, elasticity re-meshes here)."""
    return jax.make_mesh(shape, axes)


def drive_mesh(n_dev: int) -> jax.sharding.Mesh:
    """1-D fleet mesh: ``n_dev`` devices along a single ``"drives"`` axis.

    The fleet executor (core/fleet_exec.py) shard_maps each sub-batch over
    this axis — drives are embarrassingly parallel, so the 1-D mesh is the
    whole topology story: on CPU the devices are virtual cores (see
    repro.utils.hostdev), on an accelerator they are chips, and a multi-pod
    fleet is just a longer axis. Kept here, beside the production meshes,
    so every mesh the repo builds goes through one module.
    """
    devs = jax.devices()
    assert 1 <= n_dev <= len(devs), (n_dev, len(devs))
    return jax.make_mesh((n_dev,), ("drives",), devices=devs[:n_dev])


def mesh_devices(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
