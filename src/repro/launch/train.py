"""Training launcher: ``--arch <id>`` end-to-end with the fault-tolerant
runner. On CPU use a reduced config (--smoke); on a pod, the same code path
jits under the production mesh with auto shardings.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 200 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.data.pipeline import DataConfig, TokenStream
from repro.models.registry import ALL_ARCHS, get_config, get_model, smoke_config
from repro.sharding.auto import auto_shardings, batch_shardings
from repro.sharding.rules import use_sharding_rules
from repro.train.fault_tolerance import RunnerConfig, TrainRunner
from repro.train.optimizer import OptimizerConfig
from repro.train.train_loop import TrainConfig, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", choices=("none", "single", "multi"), default="none")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    api = get_model(cfg)
    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
        n_microbatches=args.microbatches,
    )
    stream = TokenStream(
        DataConfig(cfg.vocab, args.seq, args.batch)
    )

    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        ctx = use_sharding_rules(mesh)
        with ctx:
            state = init_state(api, jax.random.PRNGKey(0))
            shardings = auto_shardings(state, mesh)
            step_fn = jax.jit(
                make_train_step(api, tcfg),
                in_shardings=(shardings, batch_shardings(stream.batch(0), mesh)),
                out_shardings=(shardings, None),
                donate_argnums=(0,),
            )
    else:
        state = init_state(api, jax.random.PRNGKey(0))
        shardings = None
        step_fn = jax.jit(make_train_step(api, tcfg))

    logged = {"last": time.time()}

    def step_with_log(state, batch):
        state, metrics = step_fn(state, batch)
        n = int(state["step"])
        if n % args.log_every == 0:
            dt = time.time() - logged["last"]
            logged["last"] = time.time()
            print(
                f"step {n:5d}  loss {float(metrics['loss']):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}  ({dt:.2f}s/{args.log_every})"
            )
        return state, metrics

    runner = TrainRunner(
        step_with_log,
        state,
        stream.batch,
        RunnerConfig(
            total_steps=args.steps,
            checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir,
        ),
        shardings=shardings,
    )
    out = runner.run()
    print(
        f"done: step {out['final_step']}  loss {float(out['metrics']['loss']):.4f}  "
        f"stragglers {out['stragglers']}  recoveries {out['recoveries']}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
