import os
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={os.environ.get('DRYRUN_DEVICES', '512')} "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract memory/cost/collective analyses for the roofline report.

The two lines above MUST stay the first statements in this module — jax locks
the host device count at first backend init, and only the dry-run is allowed
to see 512 placeholder devices (smoke tests and benches see 1).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --jobs 4
Results land in reports/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import SHAPES, ShapeConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.registry import ALL_ARCHS, get_config, get_model  # noqa: E402
from repro.sharding.auto import auto_shardings, batch_shardings, cache_shardings  # noqa: E402
from repro.sharding.rules import use_sharding_rules  # noqa: E402
from repro.train.train_loop import TrainConfig, make_train_step, train_state_specs  # noqa: E402
from repro.utils.hlo import analyze_hlo, xla_cost_analysis  # noqa: E402
from repro.utils.roofline import HBM_BW, Roofline, memory_floor_bytes, model_flops  # noqa: E402

REPORT_DIR = pathlib.Path("reports/dryrun")


# ---------------------------------------------------------------------------

def count_params(params_shapes, cfg) -> dict:
    """(total, backbone=non-embedding, active=MoE-active backbone)."""
    total = backbone = expert = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params_shapes):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        total += n
        if any(k in ("embedding", "pos_embed") for k in keys):
            continue
        backbone += n
        if "moe" in keys and any(k in ("wi_gate", "wi_up", "wo") for k in keys):
            expert += n
    active = backbone
    if cfg.n_experts:
        active = backbone - expert + expert * (cfg.top_k / cfg.n_experts)
    return {"total": total, "backbone": backbone, "active": active}


def _cost_value(cost, key):
    if cost is None:
        return 0.0
    try:
        return float(cost.get(key, 0.0))
    except Exception:
        return 0.0


def _memory_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for field in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        val = getattr(ma, field, None)
        if val is not None:
            out[field] = int(val)
    if out:
        out["per_device_hbm_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


# ---------------------------------------------------------------------------

def build_lowerable(
    arch: str,
    shape: ShapeConfig,
    mesh,
    *,
    microbatches: int = 8,
    param_sharding: str = "auto",
):
    """Returns (lower_fn, model_flops_global). lower_fn() -> jax.stages.Lowered."""
    cfg = get_config(arch)
    api = get_model(cfg)
    params_shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))
    counts = count_params(params_shapes, cfg)

    if shape.kind == "train":
        mf = model_flops(counts["active"], shape.tokens, "train")
        tcfg = TrainConfig(n_microbatches=microbatches)
        step = make_train_step(api, tcfg)
        state_specs = train_state_specs(api)
        batch_specs = api.train_batch_specs(shape)
        state_sh = auto_shardings(state_specs, mesh)
        batch_sh = batch_shardings(batch_specs, mesh)

        def lower():
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            return jitted.lower(state_specs, batch_specs)

        return lower, mf, counts

    params_sh = auto_shardings(params_shapes, mesh, mode=param_sharding)

    if shape.kind == "prefill":
        mf = model_flops(counts["active"], shape.tokens, "prefill")
        input_specs = api.prefill_specs(shape)
        input_sh = batch_shardings(input_specs, mesh)

        def fn(params, inputs):
            return api.prefill(params, **inputs)

        def lower():
            jitted = jax.jit(fn, in_shardings=(params_sh, input_sh))
            return jitted.lower(params_shapes, input_specs)

        return lower, mf, counts

    # decode: one new token per sequence against a seq_len cache
    mf = model_flops(counts["active"], shape.global_batch, "decode")
    specs = api.decode_specs(shape)
    cache_sh = cache_shardings(specs["cache"], mesh)
    tok_sh = batch_shardings(
        {"tokens": specs["tokens"], "pos": specs["pos"]}, mesh
    )

    def fn(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)

    def lower():
        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, cache_sh, tok_sh["tokens"], tok_sh["pos"]),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        )
        return jitted.lower(
            params_shapes, specs["cache"], specs["tokens"], specs["pos"]
        )

    return lower, mf, counts


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    microbatches: int = 8,
    save_hlo: bool = False,
    rule_overrides: dict | None = None,
    param_sharding: str = "auto",
) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
    }
    if not cfg.supports_shape(shape):
        result["skipped"] = (
            "long_500k requires sub-quadratic attention (see DESIGN.md "
            "§Arch-applicability)"
        )
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    result["n_chips"] = n_chips
    result["mesh_shape"] = dict(mesh.shape)

    result["overrides"] = {
        "rules": rule_overrides or {},
        "param_sharding": param_sharding,
        "microbatches": microbatches,
    }
    t0 = time.time()
    with use_sharding_rules(mesh, **(rule_overrides or {})):
        lower_fn, mf, counts = build_lowerable(
            arch, shape, mesh,
            microbatches=microbatches,
            param_sharding=param_sharding,
        )
        lowered = lower_fn()
        result["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 2)

    # Primary source: trip-count-aware static analysis of the compiled HLO.
    # (compiled.cost_analysis() counts while bodies once — our scanned layers
    # would be undercounted 10–200×; kept below as a cross-reference.)
    hlo_text = compiled.as_text()
    analysis = analyze_hlo(hlo_text)
    cost = xla_cost_analysis(compiled)
    rl = Roofline(
        flops_dev=analysis["flops"],
        hbm_bytes_dev=analysis["bytes"],
        coll_bytes_dev=analysis["collective_bytes"],
        n_chips=n_chips,
        model_flops_global=mf,
    )
    # analytic memory floor (ideal-TPU traffic; static estimate above carries
    # some CPU-lowering copy noise — both reported)
    api = get_model(cfg)
    params_shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))
    params_bytes = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(params_shapes)
    )
    cache_bytes = 0
    if shape.kind != "train":
        cache_shapes = jax.eval_shape(
            lambda: api.init_cache(shape.global_batch, shape.seq_len)
        )
        cache_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache_shapes)
        )
    act_boundary = (
        cfg.n_layers * shape.tokens * cfg.d_model * 2  # bf16 boundaries
    )
    floor = memory_floor_bytes(
        shape.kind,
        params_bytes_dev=params_bytes / n_chips,
        cache_bytes_dev=cache_bytes / n_chips,
        act_boundary_bytes_dev=act_boundary / n_chips,
    )
    result.update(
        params=counts,
        memory=_memory_dict(compiled),
        collectives=analysis["collectives"],
        xla_cost_analysis={
            "flops": _cost_value(cost, "flops"),
            "bytes_accessed": _cost_value(cost, "bytes accessed"),
        },
        roofline=dict(
            rl.to_dict(),
            memory_floor_s=floor / HBM_BW,
            params_bytes=params_bytes,
            cache_bytes=cache_bytes,
        ),
    )
    if save_hlo:
        hlo_path = REPORT_DIR / f"{arch}__{shape_name}__{mesh_kind}.hlo.txt"
        hlo_path.parent.mkdir(parents=True, exist_ok=True)
        hlo_path.write_text(hlo_text)
        result["hlo_path"] = str(hlo_path)
    return result


# ---------------------------------------------------------------------------

def _cell_path(arch, shape_name, mesh_kind) -> pathlib.Path:
    return REPORT_DIR / f"{arch}__{shape_name}__{mesh_kind}.json"


def sweep(jobs: int, meshes: tuple[str, ...], force: bool = False) -> None:
    cells = [
        (arch, shape, mesh)
        for arch in ALL_ARCHS
        for shape in SHAPES
        for mesh in meshes
    ]
    pending = [
        c for c in cells if force or not _cell_path(*c).exists()
    ]
    print(f"[dryrun] {len(pending)}/{len(cells)} cells to run, jobs={jobs}")
    running: list[tuple[subprocess.Popen, tuple]] = []
    failures = []

    def drain(block: bool):
        nonlocal running
        still = []
        for proc, cell in running:
            if proc.poll() is None and not block:
                still.append((proc, cell))
                continue
            proc.wait()
            if proc.returncode != 0:
                failures.append(cell)
                print(f"[dryrun] FAIL {cell}")
            else:
                print(f"[dryrun] ok   {cell}")
        running = still

    for cell in pending:
        while len(running) >= jobs:
            drain(block=False)
            time.sleep(1.0)
        arch, shape, mesh = cell
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh,
        ]
        proc = subprocess.Popen(
            cmd,
            stdout=subprocess.DEVNULL,
            stderr=subprocess.DEVNULL,
        )
        running.append((proc, cell))
    while running:
        drain(block=False)
        time.sleep(1.0)
    print(f"[dryrun] done; {len(failures)} failures: {failures}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true", help="sweep every cell")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--param-sharding", choices=("auto", "tp"), default="auto",
        help="auto=FSDP+TP (train default); tp=TP-only (serving layout)",
    )
    ap.add_argument(
        "--override", action="append", default=[],
        help="logical sharding rule override, e.g. --override seq=model",
    )
    args = ap.parse_args(argv)
    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = None if v in ("none", "None", "") else v

    if args.all:
        sweep(args.jobs, ("single", "multi"), force=args.force)
        return 0

    assert args.arch and args.shape, "--arch/--shape required (or --all)"
    try:
        result = run_cell(
            args.arch,
            args.shape,
            args.mesh,
            microbatches=args.microbatches,
            save_hlo=args.save_hlo,
            rule_overrides=overrides,
            param_sharding=args.param_sharding,
        )
    except Exception:
        result = {
            "arch": args.arch,
            "shape": args.shape,
            "mesh": args.mesh,
            "error": traceback.format_exc(),
        }
        out = pathlib.Path(args.out) if args.out else _cell_path(
            args.arch, args.shape, args.mesh
        )
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(result, indent=2))
        print(json.dumps({"error": result["error"][-2000:]}, indent=2))
        return 1

    out = pathlib.Path(args.out) if args.out else _cell_path(
        args.arch, args.shape, args.mesh
    )
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    # console summary
    brief = {
        k: result.get(k)
        for k in ("arch", "shape", "mesh", "skipped", "lower_s", "compile_s")
    }
    if "roofline" in result:
        brief.update(
            {
                "dominant": result["roofline"]["dominant"],
                "compute_s": f'{result["roofline"]["compute_s"]:.3e}',
                "memory_s": f'{result["roofline"]["memory_s"]:.3e}',
                "collective_s": f'{result["roofline"]["collective_s"]:.3e}',
                "useful_flops": f'{result["roofline"]["useful_flops_ratio"]:.3f}',
            }
        )
        if "per_device_hbm_bytes" in result.get("memory", {}):
            brief["hbm_gb_dev"] = round(
                result["memory"]["per_device_hbm_bytes"] / 2**30, 2
            )
    print(json.dumps(brief, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
