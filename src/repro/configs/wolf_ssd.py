"""The paper's own system config (Table 2): the 16 GB simulated SSD.

Used by the SSD simulator benchmarks; ``scaled(f)`` shrinks the geometry
(ratios preserved) for CI-speed runs — equilibrium WA depends only on
LBA/PBA and B, which are kept."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    channels: int = 4
    luns_per_channel: int = 2
    blocks_per_lun: int = 1024
    pages_per_block: int = 128
    page_size: int = 16 * 1024
    lba_pba: float = 0.70

    @property
    def n_luns(self) -> int:
        return self.channels * self.luns_per_channel

    @property
    def n_blocks(self) -> int:
        return self.n_luns * self.blocks_per_lun

    @property
    def pba_pages(self) -> int:
        return self.n_blocks * self.pages_per_block

    @property
    def lba_pages(self) -> int:
        return int(self.pba_pages * self.lba_pba)

    def scaled(self, block_factor: int = 16, page_factor: int = 4) -> "SSDConfig":
        return dataclasses.replace(
            self,
            blocks_per_lun=max(4, self.blocks_per_lun // block_factor),
            pages_per_block=max(8, self.pages_per_block // page_factor),
        )


CONFIG = SSDConfig()
