"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling [hf:llava-hf/llava-v1.6-*]. The vision tower is a
STUB: input_specs() supplies precomputed anyres patch embeddings [B, S_img, d]
(S_img = seq_len/4); the LM backbone is real."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    mlp_type="swiglu",
    frontend="vision_patches",
    frontend_tokens_ratio=0.25,
)
