"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517]. Every 4th layer is sLSTM (9 mLSTM + 3 sLSTM)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    mlp_type="none",
    slstm_every=4,
    use_rope=False,
)
