"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676; hf].
SWA(1024) everywhere except global layers {0, 16, 31} (first/middle/last)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    mlp_type="swiglu",
    sliding_window=1024,
    global_attn_layers=(0, 16, 31),
    ssm_state=16,
    parallel_ssm_heads=True,
)
