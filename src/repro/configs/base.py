"""Config system: model/architecture configs and input-shape sets.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG: ModelConfig``. Shapes are global (the assignment pairs every LM arch
with the same 4-shape set); per-arch applicability (e.g. long_500k only for
sub-quadratic archs) is encoded in ``ModelConfig.supports_shape``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
ShapeKind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered for the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: ShapeKind

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The assignment's 4-shape set for the LM family (10 archs × 4 = 40 cells).
TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Every field the 10 assigned archs need."""

    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 → d_model // n_heads

    # --- attention flavour ---
    mlp_type: Literal["swiglu", "gelu", "none"] = "swiglu"
    sliding_window: int = 0           # 0 → full attention
    global_attn_layers: tuple[int, ...] = ()  # hybrid: layers w/ full attn
    rope_theta: float = 10_000.0
    use_rope: bool = True             # False → learned absolute positions
    max_position: int = 1_048_576     # learned-pos table size cap
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0                # 0 → dense
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0

    # --- SSM / recurrent ---
    ssm_state: int = 0                # mamba state size (hymba)
    slstm_every: int = 0              # xlstm: every k-th layer is sLSTM
    conv_kernel: int = 4

    # --- hybrid (hymba) ---
    parallel_ssm_heads: bool = False  # attn ∥ mamba heads in one layer

    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0         # 0 → decoder-only
    encoder_seq_ratio: int = 1        # enc frames = seq_len // ratio

    # --- modality frontend stubs ---
    frontend: Literal["none", "vision_patches", "audio_frames"] = "none"
    frontend_tokens_ratio: float = 0.0  # fraction of seq that is stub embeds

    # --- numerics ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA grouping"

    # ------------------------------------------------------------------
    @property
    def is_subquadratic(self) -> bool:
        """True if serving memory/compute does not grow quadratically with
        context (recurrent state, or sliding-window attention everywhere)."""
        if self.family == "ssm":
            return True
        if self.sliding_window > 0:
            return True
        return False

    @property
    def has_kv_cache(self) -> bool:
        return self.family != "ssm"

    def supports_shape(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k":
            return self.is_subquadratic
        return True

    # ------------------------------------------------------------------
    # Parameter count (for MODEL_FLOPS = 6·N·D roofline term)
    def param_count(self, *, active_only: bool = False) -> int:
        d, l = self.d_model, self.n_layers
        dh = self.d_head
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (self.n_heads * dh) * d

        def mlp_params(d_ff: int) -> int:
            if self.mlp_type == "swiglu":
                return 3 * d * d_ff
            if self.mlp_type == "gelu":
                return 2 * d * d_ff
            return 0

        if self.n_experts:
            experts = self.n_experts
            if active_only:
                experts = self.top_k + self.n_shared_experts
            block_mlp = experts * mlp_params(self.d_ff) + d * self.n_experts
        else:
            block_mlp = mlp_params(self.d_ff)

        if self.family == "ssm":  # xLSTM estimate: pf=2 mLSTM projections
            block = 2 * d * (2 * d) + 3 * (2 * d) * dh * self.n_heads // max(self.n_heads, 1)
            block = 6 * d * d  # up/down (4d²) + qkv/gates (~2d²)
            per_layer = block
        elif self.parallel_ssm_heads:
            per_layer = attn + block_mlp + 2 * d * d  # + mamba in/out proj
        else:
            per_layer = attn + block_mlp

        total = l * per_layer
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + attn + block_mlp)  # self+cross
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return total + emb
