"""whisper-large-v3 [audio]: enc-dec, 32L(+32L enc) d_model=1280 20H
d_ff=5120 vocab=51866, conv frontend STUB [arXiv:2212.04356].
input_specs() supplies post-conv frame embeddings [B, S/2, d]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    mlp_type="gelu",
    use_rope=False,
    max_position=32768,
    n_encoder_layers=32,
    encoder_seq_ratio=2,
    frontend="audio_frames",
)
