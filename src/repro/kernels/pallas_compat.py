"""Version-compat shims for the Pallas TPU API surface.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
0.4.x only ships the former). Kernels must not care which one the installed
jaxlib exposes, so they route every ``compiler_params=`` through here.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params struct under either JAX naming."""
    if _COMPILER_PARAMS_CLS is None:  # pragma: no cover - ancient jaxlib
        raise RuntimeError(
            "installed jax.experimental.pallas.tpu exposes neither "
            "CompilerParams nor TPUCompilerParams"
        )
    return _COMPILER_PARAMS_CLS(**kwargs)
