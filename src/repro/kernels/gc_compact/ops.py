"""Public ops: gc_compact + compact_slots (Pallas on TPU, fallback off-TPU)."""

from __future__ import annotations

import jax

from .kernel import compact_slots as _compact_slots_kernel
from .kernel import gc_compact as _kernel
from .ref import compact_slots_dense, compact_slots_ref, gc_compact_ref


def gc_compact(k_pool, v_pool, src_block, src_slot, dst_block, dst_slot):
    return _kernel(
        k_pool, v_pool, src_block, src_slot, dst_block, dst_slot,
        interpret=jax.default_backend() != "tpu",
    )


def compact_slots(slot_lba, valid, src_block, src_slot, dst_block, dst_slot):
    """Bulk-GC slot-content copy used by core/simulator's vectorized drain.

    On TPU the move list feeds the Pallas scalar-prefetch kernel. Off-TPU
    the dense one-hot lowering runs instead (identical math — asserted
    equal to both the scatter reference and the interpret-mode kernel in
    tests/test_kernels.py): this op sits inside the per-write ``lax.scan``
    of a possibly-vmapped fleet, where interpret-mode grid emulation or an
    XLA:CPU-expanded scatter loop would serialize the very hot path the
    bulk drain exists to speed up.
    """
    if jax.default_backend() == "tpu":
        return _compact_slots_kernel(
            slot_lba, valid, src_block, src_slot, dst_block, dst_slot,
            interpret=False,
        )
    return compact_slots_dense(
        slot_lba, valid, src_block, src_slot, dst_block, dst_slot
    )


__all__ = [
    "gc_compact", "gc_compact_ref",
    "compact_slots", "compact_slots_ref", "compact_slots_dense",
]
