"""Public op: gc_compact (interpret fallback off-TPU)."""

from __future__ import annotations

import jax

from .kernel import gc_compact as _kernel
from .ref import gc_compact_ref


def gc_compact(k_pool, v_pool, src_block, src_slot, dst_block, dst_slot):
    return _kernel(
        k_pool, v_pool, src_block, src_slot, dst_block, dst_slot,
        interpret=jax.default_backend() != "tpu",
    )


__all__ = ["gc_compact", "gc_compact_ref"]
