"""GC compaction (the paper's migration operation) as a Pallas TPU kernel.

Wolf's movement operations pack the live token-slots of victim KV blocks
into fresh blocks. On GPU this is a gather/scatter loop; the TPU-native
form makes the move list a scalar-prefetch operand so each (src→dst) copy
is a pair of DMA'd BlockSpec tiles — Pallas pipelines the copies.

Grid = (M,) moves. Input tile = pool[src_block[i], src_slot[i]] (one token
slot, [Hkv, D]); output tile = pool[dst_block[i], dst_slot[i]]. The output
aliases the input pool (donate) so untouched slots are preserved.

No-op rows (src_block < 0) redirect to slot (0, 0) of block dst_block[i]=src
— handled by clamping and a copy-through of the existing contents.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compact_kernel(moves_ref, src_ref, dst_cur_ref, out_ref):
    i = pl.program_id(0)
    ok = moves_ref[i, 0] >= 0

    @pl.when(ok)
    def _move():
        out_ref[...] = src_ref[...]

    @pl.when(jnp.logical_not(ok))
    def _keep():
        out_ref[...] = dst_cur_ref[...]


def _run(pool, moves, *, interpret):
    m = moves.shape[0]
    n, p, hkv, d = pool.shape

    def src_map(i, moves_ref):
        ok = moves_ref[i, 0] >= 0
        return (
            jnp.where(ok, moves_ref[i, 0], 0),
            jnp.where(ok, moves_ref[i, 1], 0),
            0,
            0,
        )

    def dst_map(i, moves_ref):
        ok = moves_ref[i, 0] >= 0
        blk = jnp.where(ok, moves_ref[i, 2], 0)
        slot = jnp.where(ok, moves_ref[i, 3], 0)
        return (blk, slot, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((None, None, hkv, d), src_map),
            pl.BlockSpec((None, None, hkv, d), dst_map),
        ],
        out_specs=pl.BlockSpec((None, None, hkv, d), dst_map),
    )
    out = pl.pallas_call(
        _compact_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={1: 0},  # pool aliases the output
        interpret=interpret,
    )(moves, pool, pool)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact_slots(
    slot_lba: jax.Array,
    valid: jax.Array,
    src_block: jax.Array,
    src_slot: jax.Array,
    dst_block: jax.Array,
    dst_slot: jax.Array,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Metadata-pool variant of :func:`gc_compact` for the simulator's
    bulk-GC drain: the pools are the [K, B] per-slot lba map and its valid
    bitmap (reshaped to [K, B, 1, 1] tiles), the move list is a victim's
    live slots. Same scalar-prefetch kernel, scalar payload."""
    moves = jnp.stack(
        [src_block, src_slot, dst_block, dst_slot], axis=1
    ).astype(jnp.int32)
    lba_new = _run(slot_lba[..., None, None], moves, interpret=interpret)
    val_new = _run(
        valid[..., None, None].astype(jnp.int32), moves, interpret=interpret
    )
    return lba_new[..., 0, 0], val_new[..., 0, 0].astype(valid.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gc_compact(
    k_pool: jax.Array,
    v_pool: jax.Array,
    src_block: jax.Array,
    src_slot: jax.Array,
    dst_block: jax.Array,
    dst_slot: jax.Array,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    moves = jnp.stack(
        [src_block, src_slot, dst_block, dst_slot], axis=1
    ).astype(jnp.int32)
    k_new = _run(k_pool, moves, interpret=interpret)
    v_new = _run(v_pool, moves, interpret=interpret)
    return k_new, v_new
