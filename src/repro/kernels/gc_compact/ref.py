"""Pure-jnp oracle for KV-block compaction (GC migration)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gc_compact_ref(
    k_pool: jax.Array,  # [N, P, Hkv, D]
    v_pool: jax.Array,  # [N, P, Hkv, D]
    src_block: jax.Array,  # [M] int32 source block per live slot (-1 = skip)
    src_slot: jax.Array,  # [M] int32
    dst_block: jax.Array,  # [M] int32 destination block
    dst_slot: jax.Array,  # [M] int32
) -> tuple[jax.Array, jax.Array]:
    """Scatter live slots (src_block, src_slot) -> (dst_block, dst_slot).

    A no-op row (src_block < 0) leaves the pool untouched.
    """
    ok = src_block >= 0
    sb = jnp.maximum(src_block, 0)
    ss = jnp.maximum(src_slot, 0)
    db = jnp.where(ok, dst_block, 0)
    ds = jnp.where(ok, dst_slot, 0)
    k_rows = k_pool[sb, ss]  # [M, Hkv, D]
    v_rows = v_pool[sb, ss]
    k_new = k_pool.at[db, ds].set(
        jnp.where(ok[:, None, None], k_rows, k_pool[db, ds])
    )
    v_new = v_pool.at[db, ds].set(
        jnp.where(ok[:, None, None], v_rows, v_pool[db, ds])
    )
    return k_new, v_new
