"""Pure-jnp oracle for KV-block compaction (GC migration)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gc_compact_ref(
    k_pool: jax.Array,  # [N, P, Hkv, D]
    v_pool: jax.Array,  # [N, P, Hkv, D]
    src_block: jax.Array,  # [M] int32 source block per live slot (-1 = skip)
    src_slot: jax.Array,  # [M] int32
    dst_block: jax.Array,  # [M] int32 destination block
    dst_slot: jax.Array,  # [M] int32
) -> tuple[jax.Array, jax.Array]:
    """Scatter live slots (src_block, src_slot) -> (dst_block, dst_slot).

    A no-op row (src_block < 0) leaves the pool untouched.
    """
    ok = src_block >= 0
    sb = jnp.maximum(src_block, 0)
    ss = jnp.maximum(src_slot, 0)
    db = jnp.where(ok, dst_block, 0)
    ds = jnp.where(ok, dst_slot, 0)
    k_rows = k_pool[sb, ss]  # [M, Hkv, D]
    v_rows = v_pool[sb, ss]
    k_new = k_pool.at[db, ds].set(
        jnp.where(ok[:, None, None], k_rows, k_pool[db, ds])
    )
    v_new = v_pool.at[db, ds].set(
        jnp.where(ok[:, None, None], v_rows, v_pool[db, ds])
    )
    return k_new, v_new


def compact_slots_ref(
    slot_lba: jax.Array,  # [K, B] int32 per-slot content (lba or -1)
    valid: jax.Array,     # [K, B] bool per-slot liveness
    src_block: jax.Array,  # [M] int32 source block per move (-1 = skip)
    src_slot: jax.Array,   # [M] int32
    dst_block: jax.Array,  # [M] int32 destination block
    dst_slot: jax.Array,   # [M] int32
) -> tuple[jax.Array, jax.Array]:
    """Metadata-pool compaction: scatter victim slot contents (the lba plus
    its valid bit) to their destination slots in one gather + one scatter.

    The simulator's bulk-GC drain is this op with pools of scalars instead
    of KV tiles. All reads happen before any write (gather-then-scatter), so
    src and dst slot sets may freely interleave across moves. A no-op row
    (src_block < 0) leaves both pools untouched.
    """
    ok = src_block >= 0
    sb = jnp.maximum(src_block, 0)
    ss = jnp.maximum(src_slot, 0)
    # redirect no-op rows out of bounds: dropped by the scatter
    db = jnp.where(ok, dst_block, slot_lba.shape[0])
    ds = jnp.where(ok, dst_slot, 0)
    lba_rows = slot_lba[sb, ss]
    valid_rows = valid[sb, ss]
    slot_lba = slot_lba.at[db, ds].set(lba_rows, mode="drop")
    valid = valid.at[db, ds].set(valid_rows, mode="drop")
    return slot_lba, valid


def compact_slots_dense(
    slot_lba: jax.Array,
    valid: jax.Array,
    src_block: jax.Array,
    src_slot: jax.Array,
    dst_block: jax.Array,
    dst_slot: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Flattened-index lowering of :func:`compact_slots_ref` for XLA:CPU.

    The 2-D ``.at[db, ds]`` scatter of the reference is expanded by
    XLA:CPU into a while loop over the update rows — inside the simulator's
    per-write scan that costs more than the entire rest of the GC drain.
    Scattering into the FLATTENED [K·B] pools with 1-D indices lowers to a
    native O(M) scatter instead (no expansion, no capacity-sized masks).
    All reads happen before any write, as in the reference.
    """
    kk, bb = slot_lba.shape
    ok = src_block >= 0
    src_flat = jnp.maximum(src_block, 0) * bb + jnp.maximum(src_slot, 0)
    dst_flat = jnp.where(ok, dst_block * bb + dst_slot, kk * bb)  # OOB drop
    lba_rows = slot_lba.reshape(-1)[src_flat]
    valid_rows = valid.reshape(-1)[src_flat]
    lba_new = slot_lba.reshape(-1).at[dst_flat].set(lba_rows, mode="drop")
    valid_new = valid.reshape(-1).at[dst_flat].set(valid_rows, mode="drop")
    return lba_new.reshape(kk, bb), valid_new.reshape(kk, bb)
