"""Pure-jnp oracle + off-TPU lowering for the fused fast-path write.

One application write on the simulator's fast path touches exactly three
mapping structures: the old physical slot's valid bit (the invalidate), the
destination slot's (lba, valid) pair (the append), and the packed
logical→physical ``page_map`` entry. ``apply_write_ref`` is the obvious
2-D-indexed formulation; ``apply_write_flat`` is the lowering the simulator
uses off-TPU — every update is a single-element dynamic-update-slice on the
FLATTENED pools, which XLA lowers natively (no scatter expansion, no
capacity-sized masks) and which stays cheap under vmap.

The TRIM peer (``apply_trim_ref`` / ``apply_trim_flat``) is the same op
minus the append: kill the old slot's valid bit and unmap the page — the
fast path of the op-stream engine's discard handling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_write_ref(
    page_map: jax.Array,  # [LBA] int32 packed physical address, -1 unmapped
    slot_lba: jax.Array,  # [K, B] int32 per-slot content (lba or -1)
    valid: jax.Array,     # [K, B] bool per-slot liveness
    lba: jax.Array,       # [] int32 page being written
    old_pm: jax.Array,    # [] int32 page's old packed address (-1 = none)
    dst_blk: jax.Array,   # [] int32 destination block (an OPEN active block)
    dst_slot: jax.Array,  # [] int32 destination slot (the block's fill ptr)
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Invalidate ``old_pm``, land ``lba`` at ``(dst_blk, dst_slot)``.

    The destination is always a fresh slot strictly above the block's
    current fill pointer, so it can never equal the old slot — the clear
    and the set commute. Returns (page_map, slot_lba, valid).
    """
    b = slot_lba.shape[1]
    has_old = old_pm >= 0
    old_c = jnp.maximum(old_pm, 0)
    ob, os = old_c // b, old_c % b
    valid = valid.at[ob, os].set(jnp.where(has_old, False, valid[ob, os]))
    new_pm = dst_blk * b + dst_slot
    slot_lba = slot_lba.at[dst_blk, dst_slot].set(lba)
    valid = valid.at[dst_blk, dst_slot].set(True)
    page_map = page_map.at[lba].set(new_pm)
    return page_map, slot_lba, valid


def apply_trim_ref(
    page_map: jax.Array,  # [LBA] int32 packed physical address, -1 unmapped
    valid: jax.Array,     # [K, B] bool per-slot liveness
    lba: jax.Array,       # [] int32 page being trimmed
    old_pm: jax.Array,    # [] int32 page's old packed address (-1 = none)
) -> tuple[jax.Array, jax.Array]:
    """The TRIM peer of :func:`apply_write_ref`: unmap ``lba`` and kill its
    physical slot. A trim of an already-unmapped page (``old_pm < 0`` —
    re-trims are legal in real discard streams) is a pure no-op.
    ``slot_lba`` keeps its stale content, exactly as an overwrite's
    invalidate does — dead slots are identified by ``valid`` alone.
    Returns (page_map, valid)."""
    b = valid.shape[1]
    has_old = old_pm >= 0
    old_c = jnp.maximum(old_pm, 0)
    ob, os = old_c // b, old_c % b
    valid = valid.at[ob, os].set(jnp.where(has_old, False, valid[ob, os]))
    # unconditional: an unmapped page's entry is already -1
    page_map = page_map.at[lba].set(-1)
    return page_map, valid


def apply_trim_flat(
    page_map: jax.Array,
    valid: jax.Array,
    lba: jax.Array,
    old_pm: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Flattened-index lowering of :func:`apply_trim_ref` (CPU/GPU path):
    one dropped-out-of-bounds single-element store per pool, mirroring
    :func:`apply_write_flat`."""
    kk, b = valid.shape
    old_c = jnp.where(old_pm >= 0, old_pm, kk * b)
    vflat = valid.reshape(-1).at[old_c].set(False, mode="drop")
    page_map = page_map.at[lba].set(-1)
    return page_map, vflat.reshape(kk, b)


def apply_write_flat(
    page_map: jax.Array,
    slot_lba: jax.Array,
    valid: jax.Array,
    lba: jax.Array,
    old_pm: jax.Array,
    dst_blk: jax.Array,
    dst_slot: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Flattened-index lowering of :func:`apply_write_ref` (CPU/GPU path).

    ``old_pm`` IS the flat index of the old slot (the packed map stores
    ``blk·B + slot``), so the invalidate needs no decode at all; a missing
    old mapping is redirected out of bounds and dropped.
    """
    kk, b = slot_lba.shape
    old_c = jnp.where(old_pm >= 0, old_pm, kk * b)
    new_pm = (dst_blk * b + dst_slot).astype(page_map.dtype)
    vflat = valid.reshape(-1)
    vflat = vflat.at[old_c].set(False, mode="drop")
    vflat = vflat.at[new_pm].set(True)
    lflat = slot_lba.reshape(-1).at[new_pm].set(lba)
    page_map = page_map.at[lba].set(new_pm)
    return page_map, lflat.reshape(kk, b), vflat.reshape(kk, b)
