"""Public op: apply_write (Pallas on TPU, flat scalar lowering off-TPU)."""

from __future__ import annotations

import jax

from .kernel import apply_write as _apply_write_kernel
from .ref import apply_write_flat, apply_write_ref


def apply_write(page_map, slot_lba, valid, lba, old_pm, dst_blk, dst_slot):
    """Fused fast-path write for core/simulator's split step: invalidate
    the old physical slot of ``lba`` and append it at (dst_blk, dst_slot)
    in one op over the three mapping pools.

    On TPU the (lba, old_pm, new_pm) row feeds the Pallas scalar-prefetch
    kernel with the pools aliased in place. Off-TPU the flattened
    single-element lowering runs instead (identical math — asserted equal
    to the 2-D reference and the interpret-mode kernel in
    tests/test_kernels.py): this op sits inside the per-write ``lax.scan``
    of a possibly-vmapped fleet, where interpret-mode grid emulation would
    serialize the very hot path the fast-path split exists to speed up.
    """
    if jax.default_backend() == "tpu":
        return _apply_write_kernel(
            page_map, slot_lba, valid, lba, old_pm, dst_blk, dst_slot,
            interpret=False,
        )
    return apply_write_flat(
        page_map, slot_lba, valid, lba, old_pm, dst_blk, dst_slot
    )


__all__ = ["apply_write", "apply_write_ref", "apply_write_flat"]
