"""Public ops: apply_write / apply_trim (Pallas on TPU, flat lowering off-TPU)."""

from __future__ import annotations

import jax

from .kernel import apply_trim as _apply_trim_kernel
from .kernel import apply_write as _apply_write_kernel
from .ref import (
    apply_trim_flat,
    apply_trim_ref,
    apply_write_flat,
    apply_write_ref,
)


def apply_write(page_map, slot_lba, valid, lba, old_pm, dst_blk, dst_slot):
    """Fused fast-path write for core/simulator's split step: invalidate
    the old physical slot of ``lba`` and append it at (dst_blk, dst_slot)
    in one op over the three mapping pools.

    On TPU the (lba, old_pm, new_pm) row feeds the Pallas scalar-prefetch
    kernel with the pools aliased in place. Off-TPU the flattened
    single-element lowering runs instead (identical math — asserted equal
    to the 2-D reference and the interpret-mode kernel in
    tests/test_kernels.py): this op sits inside the per-write ``lax.scan``
    of a possibly-vmapped fleet, where interpret-mode grid emulation would
    serialize the very hot path the fast-path split exists to speed up.
    """
    if jax.default_backend() == "tpu":
        return _apply_write_kernel(
            page_map, slot_lba, valid, lba, old_pm, dst_blk, dst_slot,
            interpret=False,
        )
    return apply_write_flat(
        page_map, slot_lba, valid, lba, old_pm, dst_blk, dst_slot
    )


def apply_trim(page_map, valid, lba, old_pm):
    """Fused fast-path TRIM: kill ``lba``'s old physical slot and unmap it
    — the discard peer of :func:`apply_write` (same dispatch rule, same
    equivalence suite). ``old_pm < 0`` (a re-trim of an already-unmapped
    page) leaves the valid pool untouched; the map entry is stored -1
    unconditionally (it already held -1).
    """
    if jax.default_backend() == "tpu":
        return _apply_trim_kernel(page_map, valid, lba, old_pm,
                                  interpret=False)
    return apply_trim_flat(page_map, valid, lba, old_pm)


__all__ = [
    "apply_write", "apply_write_ref", "apply_write_flat",
    "apply_trim", "apply_trim_ref", "apply_trim_flat",
]
