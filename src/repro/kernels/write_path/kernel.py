"""The fused fast-path write as a Pallas TPU kernel.

The simulator's steady-state write (invalidate the page's old slot, append
it to the target group's open block, repoint the packed map) is three
single-element updates on three pools. Issued as separate XLA ops they are
three kernel launches a step; the Pallas form makes the update list a
scalar-prefetch operand — one [4] int32 row ``(lba, old_pm, new_pm, ok)``
— and lands all three pools in one kernel with the pools aliased in place,
mirroring ``kernels/gc_compact``.

The pools arrive FLATTENED ([LBA] and [K·B]) and reshaped to (N, 1) tiles
so the single-element stores are plain 2-D dynamic slices. ``ok`` masks the
whole op (a disabled call must leave every pool untouched) and
``old_pm < 0`` masks just the invalidate.

``apply_trim`` is the discard peer: the same scalar-prefetch shape with the
append dropped — clear the old slot's valid bit, store -1 into the packed
map. It backs the op-stream engine's TRIM fast path on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apply_write_kernel(ops_ref, pm_ref, lba_ref, val_ref,
                        pm_out, lba_out, val_out):
    lba = ops_ref[0, 0]
    old = ops_ref[0, 1]
    new = ops_ref[0, 2]
    ok = ops_ref[0, 3] != 0

    @pl.when(ok & (old >= 0))
    def _clear():
        val_out[pl.ds(old, 1), :] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(ok)
    def _set():
        val_out[pl.ds(new, 1), :] = jnp.ones((1, 1), jnp.int32)
        lba_out[pl.ds(new, 1), :] = jnp.full((1, 1), lba, jnp.int32)
        pm_out[pl.ds(lba, 1), :] = jnp.full((1, 1), new, jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_write(
    page_map: jax.Array,  # [LBA] int32
    slot_lba: jax.Array,  # [K, B] int32
    valid: jax.Array,     # [K, B] bool
    lba: jax.Array,       # [] int32
    old_pm: jax.Array,    # [] int32, -1 = page had no mapping
    dst_blk: jax.Array,   # [] int32
    dst_slot: jax.Array,  # [] int32
    *,
    enabled: jax.Array | bool = True,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    kk, b = slot_lba.shape
    new_pm = dst_blk * b + dst_slot
    ops = jnp.stack(
        [lba, old_pm, new_pm, jnp.asarray(enabled, jnp.int32)]
    ).astype(jnp.int32)[None, :]
    out = pl.pallas_call(
        _apply_write_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((page_map.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((kk * b, 1), jnp.int32),
            jax.ShapeDtypeStruct((kk * b, 1), jnp.int32),
        ),
        input_output_aliases={1: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(
        ops,
        page_map[:, None],
        slot_lba.reshape(-1, 1),
        valid.reshape(-1, 1).astype(jnp.int32),
    )
    pm_new, lba_new, val_new = out
    return (
        pm_new[:, 0],
        lba_new[:, 0].reshape(kk, b),
        val_new[:, 0].astype(valid.dtype).reshape(kk, b),
    )


def _apply_trim_kernel(ops_ref, pm_ref, val_ref, pm_out, val_out):
    lba = ops_ref[0, 0]
    old = ops_ref[0, 1]
    ok = ops_ref[0, 2] != 0

    @pl.when(ok & (old >= 0))
    def _clear():
        val_out[pl.ds(old, 1), :] = jnp.zeros((1, 1), jnp.int32)

    @pl.when(ok)
    def _unmap():
        pm_out[pl.ds(lba, 1), :] = jnp.full((1, 1), -1, jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_trim(
    page_map: jax.Array,  # [LBA] int32
    valid: jax.Array,     # [K, B] bool
    lba: jax.Array,       # [] int32
    old_pm: jax.Array,    # [] int32, -1 = page had no mapping (no-op trim)
    *,
    enabled: jax.Array | bool = True,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    kk, b = valid.shape
    ops = jnp.stack(
        [lba, old_pm, jnp.asarray(enabled, jnp.int32)]
    ).astype(jnp.int32)[None, :]
    out = pl.pallas_call(
        _apply_trim_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((page_map.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((kk * b, 1), jnp.int32),
        ),
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(
        ops,
        page_map[:, None],
        valid.reshape(-1, 1).astype(jnp.int32),
    )
    pm_new, val_new = out
    return pm_new[:, 0], val_new[:, 0].astype(valid.dtype).reshape(kk, b)
