"""Public op: flash_attention with automatic interpret fallback on CPU.

On TPU the Pallas kernel runs natively; on CPU (tests, this container) the
kernel body executes in interpret mode, which validates the exact same
kernel logic against ref.py.
"""

from __future__ import annotations

import jax

from .kernel import flash_attention as _kernel
from .ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_kv=128):
    return _kernel(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_kv=block_kv,
        interpret=not _on_tpu(),
    )


__all__ = ["flash_attention", "flash_attention_ref"]
