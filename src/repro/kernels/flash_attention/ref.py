"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import reference_attention


def flash_attention_ref(
    q: jnp.ndarray,  # [B, Sq, Hq, D]
    k: jnp.ndarray,  # [B, Skv, Hkv, D]
    v: jnp.ndarray,  # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    return reference_attention(q, k, v, causal=causal, window=window)
