"""Flash attention forward as a Pallas TPU kernel.

Tiling: grid = (B·Hkv·G, num_q_blocks, num_kv_blocks); the KV axis is the
minor-most ("arbitrary") grid dimension, so the fp32 online-softmax
accumulators live in VMEM scratch and persist across KV iterations
(output-revisiting pattern). Block shapes are (block_q, d_head) for Q/O and
(block_kv, d_head) for K/V — multiples of 128 on the lane dim for MXU
alignment; d_head is 64 or 128 for every assigned arch.

GQA: the leading grid axis enumerates (b, h_kv, g) triples; K/V index maps
divide by G, so KV tiles are fetched once per KV head and reused by the G
query heads that share them (no repeat in HBM).

Causal + sliding-window masking is positional data; whole KV tiles strictly
above the diagonal (or outside the window) are skipped via pl.when.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # [block_q, d]
    k_ref,  # [block_kv, d]
    v_ref,  # [block_kv, d]
    o_ref,  # [block_q, d]
    m_scr,  # [block_q] f32
    l_scr,  # [block_q] f32
    acc_scr,  # [block_q, d] f32
    *,
    sm_scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_kv: int,
    seq_kv: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)
    kv_pos = ik * block_kv + jax.lax.iota(jnp.int32, block_kv)

    # tile-level skip: strictly-above-diagonal or fully-outside-window tiles
    q_max = iq * block_q + block_q - 1
    q_min = iq * block_q
    tile_needed = True
    if causal:
        tile_needed = ik * block_kv <= q_max
    if window > 0:
        tile_needed = jnp.logical_and(
            tile_needed, (ik + 1) * block_kv - 1 > q_min - window
        )

    @pl.when(tile_needed)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * sm_scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [block_q, block_kv]
        mask = kv_pos[None, :] < seq_kv
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype),
            v_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Skv, Hkv, D]
    v: jax.Array,  # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    sm_scale = d ** -0.5

    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    # zero-pad ragged tails to block multiples: partial Pallas tiles read
    # uninitialized memory (NaN in interpret mode) and 0·NaN would poison the
    # masked accumulator rows.
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    sq_orig = sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        sq += pad_q
    skv_orig = skv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        skv += pad_kv
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_kv)

    # [B, Sq, Hkv, G, D] -> leading grid axis enumerates (b, hkv, g)
    qg = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4).reshape(
        b * hkv * g, sq, d
    )
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)

    kernel = functools.partial(
        _attn_kernel,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        seq_kv=skv_orig,  # mask the zero-padded tail
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hkv * g, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((None, block_kv, d), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((None, block_kv, d), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv * g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),   # l (running sum)
            pltpu.VMEM((block_q, d), jnp.float32), # acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qg, kh, vh)
    out = out.reshape(b, hkv, g, sq, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, sq, hq, d)[:, :sq_orig]
