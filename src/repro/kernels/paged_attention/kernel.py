"""Paged decode attention as a Pallas TPU kernel (block-table indirection).

This is the device half of Wolf-KV: the host-side block manager (kvcache/)
owns block tables whose pages the paper's allocator places into temperature
groups; this kernel consumes those tables directly, so compaction /
movement operations never have to materialize a contiguous cache.

TPU adaptation of the vLLM GPU kernel: instead of per-warp gather loops, the
block table is a SCALAR-PREFETCH operand (pltpu.PrefetchScalarGridSpec) and
each grid step's BlockSpec index_map dereferences it — the page gather
becomes the kernel's input DMA, which Pallas double-buffers automatically
(HBM→VMEM overlap, the TPU-native analogue of coalesced gather warps).

Grid = (B, Hkv, num_pages); online softmax accumulates in VMEM scratch over
the page axis ("arbitrary" minor dim, output revisited on the last page).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_kernel(
    # scalar-prefetch operands
    tables_ref,  # [B, M] int32
    lengths_ref,  # [B] int32
    # array operands
    q_ref,  # [G, D] queries of this (b, hkv)
    k_ref,  # [P, D] one page of keys
    v_ref,  # [P, D] one page of values
    valid_ref,  # [P] int8 — per-slot validity (0 = eviction hole)
    o_ref,  # [G, D]
    m_scr,  # [G] f32
    l_scr,  # [G] f32
    acc_scr,  # [G, D] f32
    *,
    sm_scale: float,
    page_size: int,
):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    page_ok = (tables_ref[b, ip] >= 0) & (ip * page_size < length)

    @pl.when(page_ok)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * sm_scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [G, P]
        pos = ip * page_size + jax.lax.iota(jnp.int32, page_size)
        ok = (pos < length) & (valid_ref[...] > 0)
        s = jnp.where(ok[None, :], s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p_ = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p_, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p_.astype(v_ref.dtype),
            v_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ip == np_ - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jax.Array,  # [B, Hq, D]
    k_pool: jax.Array,  # [N, P, Hkv, D]
    v_pool: jax.Array,  # [N, P, Hkv, D]
    block_tables: jax.Array,  # [B, M] int32 (-1 = unallocated)
    lengths: jax.Array,  # [B] int32
    slot_valid: jax.Array | None = None,  # [B, M, P] (eviction holes)
    *,
    interpret: bool = False,
) -> jax.Array:
    b, hq, d = q.shape
    n, p, hkv, _ = k_pool.shape
    m = block_tables.shape[1]
    g = hq // hkv
    sm_scale = d ** -0.5
    if slot_valid is None:
        slot_valid = jnp.ones((b, m, p), jnp.int8)
    slot_valid = slot_valid.astype(jnp.int8)

    # [B, Hkv, G, D] query view; KV pool as [N, Hkv, P, D] for per-head tiles
    qg = q.reshape(b, hkv, g, d)
    kp = k_pool.swapaxes(1, 2)  # [N, Hkv, P, D]
    vp = v_pool.swapaxes(1, 2)

    def table_lookup(b_i, h_i, p_i, tables, lengths):
        del lengths
        return (jnp.maximum(tables[b_i, p_i], 0), h_i, 0, 0)

    kernel = functools.partial(
        _paged_kernel, sm_scale=sm_scale, page_size=p
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, m),
        in_specs=[
            pl.BlockSpec(
                (None, None, g, d),
                lambda b_i, h_i, p_i, tables, lengths: (b_i, h_i, 0, 0),
            ),
            pl.BlockSpec((None, None, p, d), table_lookup),
            pl.BlockSpec((None, None, p, d), table_lookup),
            pl.BlockSpec(
                (None, None, p),
                lambda b_i, h_i, p_i, tables, lengths: (b_i, p_i, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, None, g, d),
            lambda b_i, h_i, p_i, tables, lengths: (b_i, h_i, 0, 0),
        ),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables, lengths, qg, kp, vp, slot_valid)
    return out.reshape(b, hq, d)
