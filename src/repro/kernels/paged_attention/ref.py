"""Pure-jnp oracle for paged decode attention.

Layout (shared with kvcache/):
    k_pool, v_pool : [n_blocks, page_size, Hkv, D]   the global block pool
    block_tables   : [B, max_pages] int32            per-sequence page list
                     (-1 = unallocated)
    lengths        : [B] int32                       tokens in each sequence
    q              : [B, Hq, D]                      one new token per seq
Token t of sequence b lives at pool[block_tables[b, t // page], t % page].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(
    q: jax.Array,  # [B, Hq, D]
    k_pool: jax.Array,  # [N, P, Hkv, D]
    v_pool: jax.Array,  # [N, P, Hkv, D]
    block_tables: jax.Array,  # [B, M]
    lengths: jax.Array,  # [B]
    slot_valid: jax.Array | None = None,  # [B, M, P] eviction holes
) -> jax.Array:
    b, hq, d = q.shape
    n, p, hkv, _ = k_pool.shape
    m = block_tables.shape[1]
    g = hq // hkv
    # gather each sequence's KV: [B, M*P, Hkv, D]
    tables = jnp.maximum(block_tables, 0)
    k_seq = k_pool[tables].reshape(b, m * p, hkv, d)
    v_seq = v_pool[tables].reshape(b, m * p, hkv, d)
    pos = jnp.arange(m * p)
    valid = (pos[None, :] < lengths[:, None]) & (
        jnp.repeat(block_tables >= 0, p, axis=1)
    )
    if slot_valid is not None:
        valid &= slot_valid.reshape(b, m * p).astype(bool)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_seq.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v_seq.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
