"""Public op: paged_attention (interpret fallback off-TPU)."""

from __future__ import annotations

import jax

from .kernel import paged_attention as _kernel
from .ref import paged_attention_ref


def paged_attention(q, k_pool, v_pool, block_tables, lengths, slot_valid=None):
    return _kernel(
        q, k_pool, v_pool, block_tables, lengths, slot_valid,
        interpret=jax.default_backend() != "tpu",
    )


__all__ = ["paged_attention", "paged_attention_ref"]
