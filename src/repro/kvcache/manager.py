"""Wolf-KV: the paper's block manager driving a paged KV cache.

Mapping (DESIGN.md §2): KV blocks = erase blocks, token slots = flash pages,
token eviction (H2O/sliding-window style) = page invalidation, compaction =
GC migration, spare blocks = over-provisioned space, sequence churn classes =
temperature groups. Write-amplification = slots copied by compaction / slots
appended. This is the HOST control plane (numpy); block tables, validity
masks and move lists are consumed on device by kernels/paged_attention and
kernels/gc_compact.

Layout invariant (slot congruence): a sequence's cache index ci lives at
slot ci % P of block table[ci // P]; blocks are not shared across sequences
(vLLM convention), so the paged-attention kernel needs only the table + a
per-slot validity mask (eviction holes are masked, not rewritten).

Economics — exactly the paper's:
  * eviction punches holes; a group's spare blocks determine how long its
    sequences defer compaction;
  * compaction (greedy victim = most-dead sequence) rewrites the survivor
    tokens densely into FRESH blocks (the migrate-then-erase of §5.4) and
    frees the old ones — copies/reclaimed-slot falls as spare grows (the
    δ(OP) curve of eq. 3);
  * Wolf measures per-group append frequencies and splits the spare with the
    closed form (eq. 8), moving physical blocks between groups when the
    workload shifts (§5.3 movement operations);
  * the "static" baseline fixes the split once (FDP-like assumptions).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

import jax.numpy as jnp

from repro.core.allocation import allocate_closed_form


@dataclasses.dataclass
class KVGroupStats:
    size_slots: int = 0       # live token slots
    n_blocks: int = 0         # physical blocks held
    appends_interval: int = 0
    p_ewma: float = 0.0
    alloc_blocks: int = 1


@dataclasses.dataclass
class _Seq:
    group: int
    cache_len: int = 0                      # dense length incl. holes
    n_dead: int = 0                         # holes below cache_len
    blocks: list = dataclasses.field(default_factory=list)  # logical page → block
    valid: np.ndarray = None                # [cache_len] bool (grown lazily)

    def ensure(self, n):
        if self.valid is None:
            self.valid = np.zeros(max(n, 64), bool)
        elif len(self.valid) < n:
            grown = np.zeros(max(n, 2 * len(self.valid)), bool)
            grown[: len(self.valid)] = self.valid
            self.valid = grown


class WolfKVManager:
    def __init__(
        self,
        n_blocks: int,
        page_size: int,
        n_groups: int,
        *,
        adaptive: bool = True,
        interval: int = 512,
        ewma_a: float = 0.3,
        reserve_blocks: int = 2,
    ):
        self.n_blocks = n_blocks
        self.page = page_size
        self.n_groups = n_groups
        self.adaptive = adaptive
        self.interval = interval
        self.ewma_a = ewma_a
        self.reserve = reserve_blocks

        self.free: deque[int] = deque(range(n_blocks))
        self.block_group = np.full(n_blocks, -1, np.int32)
        self.block_live = np.zeros(n_blocks, np.int32)
        self.block_seq = np.full(n_blocks, -1, np.int64)
        self.groups = [KVGroupStats() for _ in range(n_groups)]
        self.seqs: dict[int, _Seq] = {}

        self.appended = 0
        self.copied = 0
        self.since_interval = 0
        self.pending_moves: list[tuple[int, int, int, int]] = []
        self._recompute_alloc()

    # -- metrics --------------------------------------------------------------
    @property
    def write_amplification(self) -> float:
        return (self.appended + self.copied) / max(self.appended, 1)

    def mark(self) -> tuple[int, int]:
        return (self.appended, self.copied)

    def wa_since(self, mark) -> float:
        da, dc = self.appended - mark[0], self.copied - mark[1]
        return (da + dc) / max(da, 1)

    # -- sequence lifecycle -----------------------------------------------------
    def add_sequence(self, seq_id: int, group: int):
        assert 0 <= group < self.n_groups
        self.seqs[seq_id] = _Seq(group=group)

    def finish_sequence(self, seq_id: int):
        seq = self.seqs.pop(seq_id)
        g = seq.group
        live = int(seq.valid[: seq.cache_len].sum()) if seq.valid is not None else 0
        self.groups[g].size_slots -= live
        for blk in seq.blocks:
            if blk >= 0:
                self._free_block(blk, g)

    # -- data path --------------------------------------------------------------
    def append_token(self, seq_id: int) -> tuple[int, int]:
        """Reserve the next cache slot; returns (block, slot) for the device
        cache write. May trigger GC / movement ops (device moves accumulate
        in self.pending_moves until drain_moves()).

        GC runs BEFORE indices are read: compaction may rewrite this very
        sequence (shrinking cache_len), so ci/blocks must be computed after.
        """
        seq = self.seqs[seq_id]
        g = seq.group
        st = self.groups[g]
        if seq.cache_len % self.page == 0 and (
            st.n_blocks >= st.alloc_blocks or len(self.free) <= self.reserve
        ):
            self.gc_group(g)
            if len(self.free) <= 1:
                best = max(range(self.n_groups), key=self._group_dead_slots)
                self.gc_group(best)
        ci = seq.cache_len
        pg = ci // self.page
        if pg >= len(seq.blocks):
            seq.blocks.append(self._claim_block(g, seq_id))
        blk = seq.blocks[pg]
        slot = ci % self.page
        seq.ensure(ci + 1)
        seq.valid[ci] = True
        seq.cache_len += 1
        self.block_live[blk] += 1
        st = self.groups[g]
        st.size_slots += 1
        st.appends_interval += 1
        self.appended += 1
        self.since_interval += 1
        if self.since_interval >= self.interval:
            self._interval_update()
        return blk, slot

    def evict_token(self, seq_id: int, ci: int):
        """Invalidate cache index ci (H2O-style). Fully-dead pages are freed
        immediately (no copies); interior holes wait for compaction."""
        seq = self.seqs[seq_id]
        assert 0 <= ci < seq.cache_len and seq.valid[ci], (ci, seq.cache_len)
        seq.valid[ci] = False
        seq.n_dead += 1
        pg = ci // self.page
        blk = seq.blocks[pg]
        self.block_live[blk] -= 1
        self.groups[seq.group].size_slots -= 1
        is_tail = pg == (seq.cache_len - 1) // self.page
        if self.block_live[blk] == 0 and not is_tail:
            self._free_block(blk, seq.group)
            seq.blocks[pg] = -1
            # page fully dead: holes in it no longer count as reclaimable
            lo, hi = pg * self.page, min((pg + 1) * self.page, seq.cache_len)
            seq.n_dead -= int((~seq.valid[lo:hi]).sum())

    # -- device views -------------------------------------------------------------
    def block_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        seq = self.seqs[seq_id]
        t = np.full(max_pages, -1, np.int32)
        n = min(len(seq.blocks), max_pages)
        t[:n] = seq.blocks[:n]
        return t

    def slot_valid(self, seq_id: int, max_pages: int) -> np.ndarray:
        seq = self.seqs[seq_id]
        v = np.zeros(max_pages * self.page, bool)
        n = min(seq.cache_len, len(v))
        if seq.valid is not None:
            v[:n] = seq.valid[:n]
        return v.reshape(max_pages, self.page)

    def cache_len(self, seq_id: int) -> int:
        return self.seqs[seq_id].cache_len

    def drain_moves(self) -> list[tuple[int, int, int, int]]:
        moves, self.pending_moves = self.pending_moves, []
        return moves

    # -- block plumbing -----------------------------------------------------------
    def _claim_block(self, g: int, seq_id: int) -> int:
        st = self.groups[g]
        if not self.free:
            # last resort: reclaim from the most-compactable group anywhere
            best = max(range(self.n_groups), key=self._group_dead_slots)
            self.gc_group(best)
        if not self.free:
            raise RuntimeError("KV pool exhausted — undersized cache")
        blk = self.free.popleft()
        self.block_group[blk] = g
        self.block_seq[blk] = seq_id
        st.n_blocks += 1
        return blk

    def _free_block(self, blk: int, g: int):
        self.block_group[blk] = -1
        self.block_seq[blk] = -1
        self.block_live[blk] = 0
        self.groups[g].n_blocks -= 1
        self.free.append(blk)

    def _group_dead_slots(self, g: int) -> int:
        return sum(
            s.n_dead for s in self.seqs.values() if s.group == g
        )

    # -- GC: sequence compaction (§5.4 migrate-then-erase) -------------------------
    def gc_group(self, g: int) -> int:
        """Compact the most-reclaimable sequence in group g. Returns slots
        copied. Survivors are rewritten densely into fresh blocks from the
        first holey page onward; old blocks are erased to the pool."""
        victims = [
            (s.n_dead, sid) for sid, s in self.seqs.items() if s.group == g and s.n_dead
        ]
        if not victims:
            return 0
        _, sid = max(victims)
        return self._compact_sequence(sid)

    def _compact_sequence(self, sid: int) -> int:
        """Rewrite the sequence densely from its first holey page onward.

        Page-wise with progressive reclamation: a source page whose survivors
        have all been scheduled is freed BEFORE the next destination block is
        claimed, so compaction needs only ~2 spare blocks regardless of
        sequence length. Device-safety: a reclaimed block can only become the
        destination of moves strictly LATER than every move reading it
        (dst ci' ≤ src ci and survivors are processed in ci order), so the
        gc_compact kernel's in-order grid has no read-after-write hazard.
        """
        seq = self.seqs[sid]
        g = seq.group
        p = self.page
        # first page containing a hole (or a freed page)
        first = None
        for pg in range(len(seq.blocks)):
            lo, hi = pg * p, min((pg + 1) * p, seq.cache_len)
            if seq.blocks[pg] < 0 or not seq.valid[lo:hi].all():
                first = pg
                break
        if first is None:
            return 0
        survivors = [
            ci for ci in range(first * p, seq.cache_len) if seq.valid[ci]
        ]
        old_blocks = list(seq.blocks)  # by page index
        n_old_pages = len(seq.blocks)
        freed_upto = first  # old pages < freed_upto have been reclaimed
        new_blocks: list[int] = []
        moves = []
        new_valid = seq.valid.copy()
        new_valid[first * p:] = False
        for i, ci in enumerate(survivors):
            nci = first * p + i
            if nci % p == 0:
                # reclaim fully-consumed source pages before claiming
                while freed_upto < ci // p:
                    blk = old_blocks[freed_upto]
                    if blk >= 0:
                        self.block_live[blk] = 0
                        self._free_block(blk, g)
                    freed_upto += 1
                new_blocks.append(self._claim_fresh(g, sid))
            dst_blk = new_blocks[nci // p - first]
            src_blk = old_blocks[ci // p]
            moves.append((src_blk, ci % p, dst_blk, nci % p))
            self.block_live[dst_blk] += 1
            new_valid[nci] = True
        # reclaim remaining old pages
        for pg in range(freed_upto, n_old_pages):
            blk = old_blocks[pg]
            if blk >= 0:
                self.block_live[blk] = 0
                self._free_block(blk, g)
        seq.blocks = old_blocks[:first] + new_blocks
        seq.cache_len = first * p + len(survivors)
        seq.valid = new_valid
        seq.n_dead = 0
        self.copied += len(moves)
        self.pending_moves.extend(moves)
        return len(moves)

    def _claim_fresh(self, g: int, sid: int) -> int:
        if not self.free:
            raise RuntimeError("pool exhausted during compaction")
        blk = self.free.popleft()
        self.block_group[blk] = g
        self.block_seq[blk] = sid
        self.groups[g].n_blocks += 1
        return blk

    # -- Wolf control plane (§5.1/§5.3/§5.5) ----------------------------------------
    def _interval_update(self):
        self.since_interval = 0
        total = sum(st.appends_interval for st in self.groups) or 1
        for st in self.groups:
            u = st.appends_interval / total
            st.p_ewma = st.p_ewma * (1 - self.ewma_a) + self.ewma_a * u
            st.appends_interval = 0
        if self.adaptive:
            self._recompute_alloc()
            self.movement_ops()

    def _recompute_alloc(self):
        s = np.array([max(st.size_slots, 1) for st in self.groups], np.float32)
        p = np.array([st.p_ewma for st in self.groups], np.float32)
        if p.sum() <= 0:
            p = s / s.sum()
        usable = (self.n_blocks - self.reserve - 2 * self.n_groups - 1) * self.page
        op_total = max(usable - float(s.sum()), float(self.n_groups))
        op = np.asarray(
            allocate_closed_form(jnp.asarray(s), jnp.asarray(p), op_total)
        )
        for g, st in enumerate(self.groups):
            st.alloc_blocks = max(1, int(np.ceil((s[g] + op[g]) / self.page)))

    def movement_ops(self):
        """§5.3: compact block-surplus groups greedily, returning blocks to
        the pool for deficit groups (any-to-any donation via the pool)."""
        for _ in range(self.n_blocks):
            excess, g = max(
                (st.n_blocks - st.alloc_blocks, gi)
                for gi, st in enumerate(self.groups)
            )
            if excess < 1 or len(self.free) < 2:
                return
            if self.gc_group(g) == 0:
                return

    # -- integrity (tests) ------------------------------------------------------------
    def check_invariants(self):
        assert (self.block_live >= 0).all()
        live_total = 0
        for sid, seq in self.seqs.items():
            live = int(seq.valid[: seq.cache_len].sum()) if seq.valid is not None else 0
            live_total += live
            for pg, blk in enumerate(seq.blocks):
                if blk >= 0:
                    assert self.block_group[blk] == seq.group
                    assert self.block_seq[blk] == sid
        assert live_total == int(self.block_live.sum())
        for g, st in enumerate(self.groups):
            assert st.n_blocks == int((self.block_group == g).sum())
            assert st.size_slots == int(self.block_live[self.block_group == g].sum())
        assert len(self.free) == int((self.block_group == -1).sum())
