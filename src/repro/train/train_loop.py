"""Training step: gradient-accumulated, remat'd, mixed-precision.

``make_train_step(api, train_cfg)`` builds the jit-able
``train_step(state, batch) -> (state, metrics)`` that the launcher lowers /
runs. Distribution is declared by shardings (sharding/auto.py); this module
is mesh-agnostic SPMD code.

Distributed-optimization features:
  * microbatched gradient accumulation (lax.scan) — bounds activation memory
    and lets XLA overlap per-microbatch reduce-scatters with compute;
  * fp32 or bf16(+error-feedback) gradient accumulators
    (``accum_dtype="bfloat16"`` halves accumulator bandwidth; the residual
    feedback keeps convergence — see sharding/gradient.py for the collective-
    level compression used on the pod axis);
  * per-layer remat is inside each model's ``forward_hidden``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi
from repro.train.optimizer import OptimizerConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptimizerConfig = OptimizerConfig()
    n_microbatches: int = 1
    accum_dtype: str = "float32"


def init_state(api: ModelApi, rng) -> dict:
    params = api.init_params(rng)
    return {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree_util.tree_map(split, batch)


def make_train_step(api: ModelApi, tcfg: TrainConfig) -> Callable:
    acc_dt = jnp.dtype(tcfg.accum_dtype)

    def train_step(state: dict, batch: dict):
        params = state["params"]
        grad_fn = jax.value_and_grad(api.loss_fn)

        if tcfg.n_microbatches <= 1:
            loss, grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, tcfg.n_microbatches)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )

            def body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g
                )
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), mbs
            )
            loss = loss / tcfg.n_microbatches
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.n_microbatches, grads
            )

        new_params, new_opt, metrics = adamw_update(
            grads, state["opt"], params, tcfg.opt
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def train_state_specs(api: ModelApi) -> Any:
    """Abstract (ShapeDtypeStruct) train state for dry-run lowering."""
    return jax.eval_shape(lambda: init_state(api, jax.random.PRNGKey(0)))
