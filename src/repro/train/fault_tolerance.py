"""Fault-tolerant training runner: checkpoint/restart, failure injection,
straggler accounting, elastic resume.

On a real 1000+-node fleet this wraps the per-host main():
  * periodic atomic checkpoints (train/checkpoint.py) — restart-safe;
  * any step exception → restore latest checkpoint and continue (bounded
    retries); data is stateless-by-step (data/pipeline.py) so no epoch state
    needs recovery;
  * step-time watchdog: steps slower than ``straggler_factor ×`` the running
    median are counted and surfaced — the fleet scheduler's signal to
    hot-swap a host (here: logged; on Borg/K8s: eviction hook);
  * elastic: resume on a different mesh by passing new shardings to
    restore (the checkpoint stores logical arrays, not device layouts).

Failure injection (``failure_at``) exists so tests can prove the recovery
path actually works rather than assuming it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    max_retries: int = 3
    straggler_factor: float = 3.0


class InjectedFailure(RuntimeError):
    pass


class TrainRunner:
    def __init__(
        self,
        step_fn: Callable[[Any, dict], tuple[Any, dict]],
        init_state: Any,
        batch_fn: Callable[[int], dict],
        cfg: RunnerConfig,
        *,
        shardings: Any = None,
        failure_at: Optional[int] = None,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.shardings = shardings
        self.failure_at = failure_at
        self._injected = False
        self.state = init_state
        self.step = 0
        self.retries = 0
        self.step_times: list[float] = []
        self.stragglers = 0
        self.recoveries = 0

    # ------------------------------------------------------------------
    def _maybe_resume(self):
        last = latest_step(self.cfg.checkpoint_dir)
        if last is not None:
            self.state, self.step = restore_checkpoint(
                self.cfg.checkpoint_dir, self.state, shardings=self.shardings
            )
            self.recoveries += 1

    def _watchdog(self, dt: float):
        self.step_times.append(dt)
        if len(self.step_times) >= 8:
            med = float(np.median(self.step_times[-64:]))
            if dt > self.cfg.straggler_factor * med:
                self.stragglers += 1

    def run(self) -> dict:
        self._maybe_resume()
        while self.step < self.cfg.total_steps:
            if (
                self.failure_at is not None
                and self.step == self.failure_at
                and not self._injected
            ):
                self._injected = True
                raise_step = self.step
                try:
                    raise InjectedFailure(f"injected at step {raise_step}")
                except InjectedFailure:
                    if self.retries >= self.cfg.max_retries:
                        raise
                    self.retries += 1
                    self._maybe_resume()
                    continue
            t0 = time.time()
            batch = self.batch_fn(self.step)
            self.state, metrics = self.step_fn(self.state, batch)
            self._watchdog(time.time() - t0)
            self.step += 1
            if self.step % self.cfg.checkpoint_every == 0:
                save_checkpoint(self.cfg.checkpoint_dir, self.state, self.step)
        save_checkpoint(self.cfg.checkpoint_dir, self.state, self.step)
        return {
            "final_step": self.step,
            "retries": self.retries,
            "recoveries": self.recoveries,
            "stragglers": self.stragglers,
            "metrics": metrics,
        }
