"""AdamW in pure JAX, with a mixed-precision master-copy layout.

Layout (production TPU convention):
  * model params: cfg.dtype (bf16 on the target) — what forward/backward see
  * optimizer state: fp32 m, fp32 v, fp32 master params
  * update math in fp32; bf16 params re-cast from the master every step

The state tree mirrors the param tree, so the auto-sharder (FSDP+TP) applies
to it unchanged — ZeRO-style sharded optimizer state for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decayed = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def adamw_init(params: Any) -> dict:
    f32 = lambda p: jnp.zeros_like(p, jnp.float32)
    master = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return {
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
        "master": master,
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    grads: Any,
    opt_state: dict,
    params: Any,
    cfg: OptimizerConfig,
) -> tuple[Any, dict, dict]:
    """Returns (new params in model dtype, new opt state, metrics)."""
    count = opt_state["count"] + 1
    lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    # tree_map over four trees at once
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    flat_p = treedef.flatten_up_to(params)
    new_m, new_v, new_w, new_p = [], [], [], []
    for g, m, v, w, p in zip(flat_g, flat_m, flat_v, flat_w, flat_p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w
        w = w - lr * step
        new_m.append(m)
        new_v.append(v)
        new_w.append(w)
        new_p.append(w.astype(p.dtype))
    unflat = jax.tree_util.tree_unflatten
    new_state = {
        "m": unflat(treedef, new_m),
        "v": unflat(treedef, new_v),
        "master": unflat(treedef, new_w),
        "count": count,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return unflat(treedef, new_p), new_state, metrics
