"""Sharded, elastic checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
             manifest.json         tree structure, shapes, dtypes, step
             shard_<host>.npz      this host's addressable array shards

Multi-host posture: every host writes only its addressable shards; restore
reads all shard files and assembles per-leaf global arrays, then device_puts
with the TARGET mesh's shardings — so a checkpoint taken on a 16×16 mesh
restores onto 2×16×16 (or 1 device) unchanged: ELASTIC by construction,
because the manifest stores logical content, not device layout.

Atomicity: written to ``<dir>/.tmp_step_N`` then os.rename (POSIX-atomic) —
a crash mid-save never corrupts the latest complete checkpoint.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(
    directory: str | os.PathLike,
    state: Any,
    step: int,
    *,
    host_id: int = 0,
    keep: int = 2,
) -> pathlib.Path:
    directory = pathlib.Path(directory)
    tmp = directory / f".tmp_step_{step}"
    final = directory / f"step_{step}"
    tmp.mkdir(parents=True, exist_ok=True)

    leaves = _flatten(state)
    manifest = {
        "step": step,
        "leaves": {
            key: {"shape": list(np.shape(leaf)), "dtype": str(np.asarray(leaf).dtype)}
            for key, leaf in leaves
        },
    }
    arrays = {}
    for key, leaf in leaves:
        arr = leaf
        if isinstance(arr, jax.Array):
            # gather this host's addressable data (full array on 1 host)
            arr = np.asarray(arr)
        arrays[key.replace("/", "__")] = np.asarray(arr)
    np.savez(tmp / f"shard_{host_id}.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    # retention
    steps = sorted(
        (int(p.name.split("_")[1]), p)
        for p in directory.glob("step_*")
        if p.name.split("_")[1].isdigit()
    )
    for _, old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.glob("step_*")
        if p.name.split("_")[1].isdigit()
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str | os.PathLike,
    target: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of NamedSharding
    for the CURRENT mesh (elastic restore)."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt = directory / f"step_{step}"
    data: dict[str, np.ndarray] = {}
    for shard_file in sorted(ckpt.glob("shard_*.npz")):
        with np.load(shard_file) as z:
            for k in z.files:
                data[k] = z[k]

    leaves_t = _flatten(target)
    shard_leaves = _flatten(shardings)[: len(leaves_t)] if shardings else None
    restored = []
    for i, (key, leaf) in enumerate(leaves_t):
        arr = data[key.replace("/", "__")]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i][1])
        restored.append(arr)
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, restored), step
