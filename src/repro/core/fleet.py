"""Fleet-scale batched SSD simulation: B drives in one jitted vmap(lax.scan),
shard_mapped over a 1-D drive-axis device mesh.

Where ``managers.simulate`` runs ONE drive per Python call, a fleet stacks
the per-drive state pytrees and runs every drive lock-step through the same
compiled write-step — per-drive differences (workload, seed, FDP assumption
arrays, allocation / GC / detector / movement policy, group-count caps, and
the §5.1 constants ``ewma_a`` / interval length) are traced data, so wolf,
wolf-dynamic, fdp and single-group drives — and EWMA/interval sweeps — batch
into one ``vmap``. This is the substrate for exploring policy × workload grids
("as many scenarios as you can imagine"): per-drive write streams are drawn
on device by ``workloads.sample_phases_device`` inside the jitted region, so
host work is O(B) setup, not O(B·T) sampling.

Execution architecture (core/fleet_exec.py owns the device side):

* **Partitioning** — drives are split into sub-batches by step STRUCTURE,
  the :func:`_part_key` of (detector, movement ops, dynamic groups,
  closed-form allocation, op stream): a vmapped ``lax.cond`` lowers to a
  select over both branches, so any machinery one drive of a sub-batch
  carries is machinery every drive of that sub-batch executes per step.
  Partitioning keeps the (G × bits) bloom filter pair, the §5.6
  GC-demotion scan, and the movement-op second drain out of the compiled
  step of drives that can never use them.
* **Sharding** — ``devices=`` runs each sub-batch as
  ``jit(shard_map(vmap(scan)))`` over the ``"drives"`` axis of
  :func:`repro.launch.mesh.drive_mesh`; each device scans its slice of the
  batch, bit-identical to the single-device vmap (no cross-drive ops, no
  collectives). A ragged sub-batch (size not a multiple of the device
  count) is padded with inert filler drives and the filler rows are
  dropped from every result: per-device wall-clock is ceil(B/n_dev) drive
  scans either way, so the pad only fills otherwise-idle lanes — padding
  is free, which is why it replaced the old divisor clamp that silently
  collapsed ragged sub-batches to 1 device. (The ``pmap(vmap(...))``
  executor this supersedes is fully removed: shard_map composes with jit —
  one dispatch, donated state buffers, one compilation cache.)
* **Pipelining** — sub-batches are DISPATCHED in one pass and RESOLVED in a
  second: jax dispatch is asynchronous, so while sub-batch k executes on
  the devices the host is already building (``build_drive``, stacking,
  padding) sub-batch k+1. Host-side construction overlaps device
  execution instead of serializing with it, which is where the old
  executor spent its host time on large grids.
* **Compile amortization** — per-sub-batch runners are memoized on
  (step structure × geometry × scan length × device count), with optional
  on-disk persistence (``fleet_exec.enable_persistent_compilation_cache``),
  so sweeps that revisit a structure compile once. On CPU, spawn virtual
  devices via :func:`repro.utils.hostdev.force_host_device_count` *before*
  the first jax import — the device count is locked at backend init (that
  is also why ``devices="auto"`` from a jax-already-imported entry point
  warns instead of silently running on 1 device).

Degraded drives are inert lanes, like filler drives: the fault-injection
layer (see simulator.py's fault section) freezes a drive whose spare pool
is exhausted via the traced ``drive_status`` + halt guard — every later op
is a counted no-op on frozen-valid state. That is exactly the mechanism
the mesh padding above uses for ragged sub-batches (a filler drive is a
replicated row whose results are dropped), so a drive dying mid-scan never
poisons its vmapped/shard_mapped sub-batch: survivors' lanes are
elementwise untouched (tests/test_faults.py pins survivors bit-identical
to running them alone), and the dead lane keeps producing valid (frozen)
buffers until the scan ends. ``FleetResult.drive_status()`` /
``retired_fraction()`` / ``time_to_degraded()`` / ``wa_vs_lifetime()``
report the survival story per drive.

Geometry is shared at the SHAPE level (array sizes: blocks, pages/block,
logical span, group slots); within that shape, drives vary utilization and
locality through their phase mix (e.g. a zero-probability cold tail emulates
a shorter logical span at identical state shapes) — and, as of the op-stream
engine, through TRIMs: drives whose phases carry ``trim_probs`` run the
WRITE/TRIM dispatch step in their own sub-batch (``_part_key``), so one
fleet sweeps utilization × trim-rate × policy while pure-write drives keep
their exact historical streams and step. ``FleetResult.trim_fraction()`` /
``predicted_wa()`` read the carried effective-utilization counters for the
Frankie-style effective-OP analytics.
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fleet_exec import (
    SubbatchFailure,
    SubbatchResolutionError,
    enable_persistent_compilation_cache,
    pad_batch,
    resolve_devices,
    subbatch_runner,
)
from repro.core.managers import RunResult, build_drive
from repro.core.simulator import SimContext, policy_from_config
from repro.core.ssd import Geometry, ManagerConfig, SimState
from repro.core.workloads import Phase, phase_param_arrays

# ManagerConfig fields that must agree fleet-wide: they are baked into the
# shared static SimContext (paper constants), not per-drive policy data.
# interval_frac and ewma_a are NOT here: the §5.1 constants ride in the
# traced per-drive policy pytree, so fleets can sweep them in one batch.
_SHARED_FIELDS = (
    "q_create", "w_intervals",
    "cold_hit_rate_frac", "cold_op_frac", "gc_reserve_blocks",
    "bloom_bits_per_page", "valve_max_tries", "bloom_rotate_min_writes",
    # the retry ladder depth is a static exponent in the compiled fault
    # hook (rate^(1+retries)), not traced policy data — see
    # simulator._erase_fault_retire; the RATES themselves are per-drive
    "erase_max_retries",
)


@dataclasses.dataclass(frozen=True)
class DriveSpec:
    """One drive of a fleet: a manager preset over a phase sequence."""

    mcfg: ManagerConfig
    phases: tuple[Phase, ...]
    seed: int = 0
    name: str | None = None

    @property
    def label(self) -> str:
        return self.name or f"{self.mcfg.name}#{self.seed}"


@dataclasses.dataclass
class FleetResult:
    app: np.ndarray  # [B, T // trace_every] cumulative application writes
    mig: np.ndarray  # [B, T // trace_every] cumulative migrations
    specs: list[DriveSpec]
    # (original drive indices, stacked SimState pytree) per sub-batch
    shards: list[tuple[list[int], SimState]]
    lbas: np.ndarray | None = None  # [B, T] when return_lbas=True
    geom: Geometry | None = None  # shared fleet geometry (analytics)
    trace_every: int = 1  # trace stride (RunResult.stride of every drive)
    # per-sub-batch executor report, aligned with .shards: drive count,
    # devices actually used, and filler-drive padding. With mesh padding a
    # ragged sub-batch always uses every requested device (devices ==
    # min(requested, len(jax.devices()))) — this is the visible record of
    # the effective shard count the old divisor clamp used to hide.
    exec_meta: list[dict] = dataclasses.field(default_factory=list)

    @property
    def devices_used(self) -> int:
        """Device count the fleet actually sharded over (max across
        sub-batches; 1 = pure single-device vmap)."""
        return max((m["devices"] for m in self.exec_meta), default=1)

    def state(self, i: int) -> SimState:
        """Final state pytree of drive i."""
        for idx, states in self.shards:
            if i in idx:
                pos = idx.index(i)
                return jax.tree_util.tree_map(lambda a: a[pos], states)
        raise IndexError(i)

    @property
    def states(self) -> SimState:
        """Stacked state pytree — only for single-shard (unpartitioned)
        fleets; mixed bloom/non-bloom fleets must use .state(i)."""
        assert len(self.shards) == 1, "mixed fleet: use .state(i)"
        return self.shards[0][1]

    def result(self, i: int) -> RunResult:
        """Per-drive view with the single-drive RunResult API."""
        return RunResult(
            self.app[i], self.mig[i], self.state(i), stride=self.trace_every
        )

    @property
    def wa_total(self) -> np.ndarray:
        """[B] end-to-end write amplification per drive."""
        return (self.app[:, -1] + self.mig[:, -1]) / np.maximum(
            self.app[:, -1], 1
        )

    def wa_curves(self, window: int = 2000) -> np.ndarray:
        """[B, K] windowed WA over time per drive."""
        return np.stack(
            [self.result(i).wa_curve(window) for i in range(len(self.specs))]
        )

    # -- closed-form analytics (paper eq. 3/5 + Frankie effective OP) -------

    def trim_fraction(self) -> np.ndarray:
        """[B] fraction of the logical span each drive holds TRIMMED at its
        final state (0.0 for pure-write drives) — read off the carried
        ``mapped_pages`` counter, no page_map reduction."""
        assert self.geom is not None, "fleet built without geometry"
        lba = self.geom.lba_pages
        return np.array([
            1.0 - float(self.state(i)["mapped_pages"]) / lba
            for i in range(len(self.specs))
        ])

    def predicted_wa(self) -> np.ndarray:
        """[B] closed-form model WA per drive at its final operating point.

        Each active group is treated as a uniform sub-SSD of EFFECTIVE
        logical size ``grp_live`` (mapped pages — trimmed pages act as
        dynamic over-provisioning, Frankie et al.) with over-provisioning
        ``grp_alloc·B − grp_live``, so its δ solves eq. 4 (≡ eq. 3 per
        group); the drive prediction is the frequency-weighted sum of the
        per-group WAs (eq. 5), weighted by the measured EWMA frequencies.
        A single-group pure-write drive degenerates to the plain eq. 3
        equilibrium model; a trimmed one to eq. 3 at the post-trim
        utilization (``effective_op_ratio``).
        """
        from repro.core.allocation import total_wa

        assert self.geom is not None, "fleet built without geometry"
        b = self.geom.pages_per_block
        out = np.zeros(len(self.specs))
        for i in range(len(self.specs)):
            st = self.state(i)
            active = np.asarray(st["grp_active"])
            s = np.asarray(st["grp_live"], np.float64)  # effective sizes
            op_x = np.asarray(st["grp_alloc"], np.float64) * b - s
            p = np.where(active, np.asarray(st["grp_p"], np.float64), 0.0)
            if p.sum() <= 0.0:  # no interval completed yet: weight by size
                p = np.where(active, s, 0.0)
            p = p / max(p.sum(), 1e-12)
            s_safe = np.where(active & (s > 0), s, 1.0)
            out[i] = float(
                total_wa(
                    jnp.asarray(s_safe, jnp.float32),
                    jnp.asarray(p, jnp.float32),
                    jnp.asarray(np.maximum(op_x, 0.0), jnp.float32),
                )
            )
        return out

    # -- wear / endurance analytics (per-block P-E counts) ------------------

    def wear_variance(self) -> np.ndarray:
        """[B] population variance of per-block erase counts, from the O(1)
        carried aggregates (no block-array reduction)."""
        from repro.core.analytics import wear_variance

        assert self.geom is not None, "fleet built without geometry"
        k = self.geom.n_blocks
        return np.array([
            float(wear_variance(
                self.state(i)["erase_total"],
                self.state(i)["erase_sq_total"], k,
            ))
            for i in range(len(self.specs))
        ])

    def wear_imbalance(self) -> np.ndarray:
        """[B] max/mean P-E ratio per drive (1.0 = perfectly level)."""
        from repro.core.analytics import wear_imbalance

        return np.array([
            float(wear_imbalance(self.state(i)["erase_count"]))
            for i in range(len(self.specs))
        ])

    def lifetime_dwpd(self, *, pe_cycles: float = 3000.0,
                      years: float = 5.0) -> np.ndarray:
        """[B] sustainable drive-writes-per-day over a warranty window,
        projecting each drive's measured WA and wear imbalance onto a NAND
        P-E budget (default 3k cycles, TLC-class)."""
        from repro.core.analytics import (
            dwpd_from_lifetime,
            lifetime_host_writes,
        )

        assert self.geom is not None, "fleet built without geometry"
        host = lifetime_host_writes(
            n_blocks=self.geom.n_blocks,
            pages_per_block=self.geom.pages_per_block,
            pe_cycles=pe_cycles,
            wa=jnp.asarray(self.wa_total, jnp.float32),
            imbalance=jnp.asarray(self.wear_imbalance(), jnp.float32),
        )
        return np.asarray(dwpd_from_lifetime(
            host, lba_pages=self.geom.lba_pages, years=years
        ))

    # -- survival / endurance analytics (fault-injection layer) -------------

    def drive_status(self) -> np.ndarray:
        """[B] traced drive status at the final state: 0 = STATUS_OK,
        1 = STATUS_DEGRADED (spares exhausted or pool death — the drive
        froze into an inert lane; see simulator._erase_fault_retire)."""
        return np.array([
            int(self.state(i)["drive_status"])
            for i in range(len(self.specs))
        ])

    def retired_fraction(self) -> np.ndarray:
        """[B] fraction of each drive's physical blocks in the terminal
        RETIRED state — the capacity the §5.5 allocator has lost (0.0 for
        fault-free drives)."""
        assert self.geom is not None, "fleet built without geometry"
        k = self.geom.n_blocks
        return np.array([
            float(self.state(i)["retired_blocks"]) / k
            for i in range(len(self.specs))
        ])

    def time_to_degraded(self) -> np.ndarray:
        """[B] application-write index at which each drive degraded, or -1
        for drives still in service at the end of the run — the fleet's
        time-to-failure curve (plot survival with
        ``analytics.survival_fraction``)."""
        return np.array([
            int(self.state(i)["degraded_at"])
            for i in range(len(self.specs))
        ])

    def wa_vs_lifetime(self, window: int = 2000) -> np.ndarray:
        """[B, K] windowed WA over each drive's lifetime, NaN once the
        drive is degraded (frozen windows complete no application writes)
        — the WA-vs-lifetime curve of the aging study
        (``analytics.wa_vs_lifetime`` computes one drive's curve)."""
        from repro.core.analytics import wa_vs_lifetime

        return np.stack([
            wa_vs_lifetime(
                self.app[i], self.mig[i], window=window,
                stride=self.trace_every,
            )
            for i in range(len(self.specs))
        ])

    def model_error(self, window: int = 2000, tail: int = 3,
                    pred: np.ndarray | None = None) -> np.ndarray:
        """[B] relative error of the eq. 3/5 prediction vs the simulated
        equilibrium WA (mean of the last ``tail`` windows per drive).
        The prediction consumes each drive's effective (post-trim)
        utilization — ``grp_live``/``grp_alloc`` at the final state — so
        trimmed and pure-write drives are judged by the same model.

        pred: pass a precomputed :meth:`predicted_wa` to avoid running the
        per-drive closed-form pass twice.
        """
        if pred is None:
            pred = self.predicted_wa()
        measured = np.array([
            float(np.mean(self.result(i).wa_curve(window)[-tail:]))
            for i in range(len(self.specs))
        ])
        return (pred - measured) / np.maximum(measured, 1e-12)


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _spec_has_trim(s: DriveSpec) -> bool:
    return any(ph.has_trim for ph in s.phases)


def _part_key(s: DriveSpec) -> tuple[str, bool, bool, bool, bool]:
    """Sub-batch partition key: step STRUCTURE a drive's compiled scan must
    carry. A vmapped lax.cond lowers to a select over both branches, so
    machinery any drive of a sub-batch carries is machinery every drive of
    that sub-batch executes per step. Keying on (detector, movement ops,
    dynamic groups, closed-form allocation, op stream) keeps the [G, bits]
    filter pair and §5.6 demotion machinery out of static-detector drives,
    the movement-op compaction (a second full GC drain per step) out of
    fdp/single-style drives, the §5.2/eq.-8 interval machinery (two
    argsorts + an 80-iteration bisection per interval) out of drives that
    never run it, and the WRITE/TRIM dispatch (plus its per-drive §5.1
    interval predicate) out of pure-write drives — which also keeps their
    device-sampled streams bit-identical to the pre-op-stream engine. The
    detector is part of the key, so every sub-batch is td-homogeneous and
    the simulator dispatches it at trace time."""
    return (
        s.mcfg.td_mode,
        s.mcfg.movement_ops,
        s.mcfg.dynamic_groups,
        s.mcfg.alloc_mode in ("wolf", "optimal", "fdp_assumed"),
        _spec_has_trim(s),
    )


def simulate_fleet(
    geom: Geometry,
    specs: list[DriveSpec],
    *,
    sampler: str = "jax",
    init_p_from_phase: bool = True,
    return_lbas: bool = False,
    devices: int | str | None = None,
    gc_impl: str = "bulk",
    fast_path: bool = False,
    trace_every: int = 1,
    unroll: int = 1,
    ops_stream: bool | None = None,
) -> FleetResult:
    """Run B independent drives in a single jitted vmap(lax.scan).

    sampler: "jax" draws every write stream on device inside the jitted
    region (fast path); "numpy" replays the exact host streams
    ``managers.simulate`` would draw for the same (phases, seed) — the two
    paths then agree elementwise, which tests/test_fleet.py asserts.

    ops_stream: None (default) routes each drive through the op-stream
    engine iff its phases carry TRIMs (the partition key separates them,
    so pure-write drives keep their exact historical streams and step);
    True forces EVERY drive through the op engine — with the numpy
    sampler the events are then draw-for-draw identical on pure-write
    phases, the bit-compatibility anchor of tests/test_write_engine.py.

    devices: None/1 = pure single-device vmap; "auto" = shard over all
    jax.devices(); int = shard over that many (clamped to the visible
    device count). Every sub-batch — ragged or not — uses the full
    resolved device count: ragged sub-batches are padded with inert
    filler drives (free: the pad fills otherwise-idle lanes) and the
    filler rows never surface in results. ``FleetResult.exec_meta``
    records drives/devices/padding per sub-batch. Results are
    bit-identical across device counts. NOTE: on CPU the visible device
    count is locked at jax backend init — see
    ``repro.utils.hostdev.force_host_device_count``.

    gc_impl: GC drain implementation ("bulk" | "reference"), threaded to
    SimContext — the bulk-vs-reference equivalence suite runs whole fleets
    under both.

    fast_path: step engine. The fleet default is the single-path step
    (False): under vmap a lax.cond executes BOTH branches and selects, so
    the split engine's lean branch is pure extra work here — it pays off
    under plain jit (managers.simulate, accelerator per-core scans), where
    the heavy tail is a real untaken branch. Both engines are elementwise-
    identical (tests/test_write_engine.py), so this is a pure scheduling
    knob. trace_every / unroll: trace stride and scan unroll
    (simulator.scan_writes); trace_every must divide n_total, and app/mig
    come back [B, n_total // trace_every].

    Every spec must issue the same total number of writes (one shared scan).
    """
    assert specs, "empty fleet"
    if sampler not in ("jax", "numpy"):
        raise ValueError(f"unknown sampler {sampler!r}")
    if ops_stream is False:  # mirror managers.simulate: fail loudly
        assert not any(_spec_has_trim(s) for s in specs), (
            "specs carry TRIMs: ops_stream=False is not available"
        )
    totals = {sum(ph.n_writes for ph in s.phases) for s in specs}
    assert len(totals) == 1, f"drives must issue equal write totals: {totals}"
    n_total = totals.pop()
    assert n_total % trace_every == 0, (n_total, trace_every)
    base = specs[0].mcfg
    for s in specs:
        for f in _SHARED_FIELDS:
            assert getattr(s.mcfg, f) == getattr(base, f), (
                f"fleet drives must share ManagerConfig.{f} "
                "(a static paper constant)"
            )
    # on-disk compile cache: strictly opt-in via env — see the hazard
    # note on enable_persistent_compilation_cache (jaxlib 0.4.37/XLA:CPU
    # heap corruption when serializing the Pallas-bearing executables)
    if os.environ.get("REPRO_JAX_CACHE_DIR"):
        enable_persistent_compilation_cache()
    n_dev = resolve_devices(devices)
    p_max = max(len(s.phases) for s in specs)
    g_wl = max(len(ph.sizes) for s in specs for ph in s.phases)

    def part_key(s: DriveSpec):
        key = _part_key(s)
        if ops_stream:  # force every drive onto the op engine
            key = key[:-1] + (True,)
        return key

    partitions: list[tuple[tuple, list[int]]] = []
    for key in sorted({part_key(s) for s in specs}):
        partitions.append(
            (key, [i for i, s in enumerate(specs) if part_key(s) == key])
        )

    n_trace = n_total // trace_every
    app = np.zeros((len(specs), n_trace), np.int32)
    mig = np.zeros((len(specs), n_trace), np.int32)
    lbas_out = np.zeros((len(specs), n_total), np.int32) if return_lbas else None
    shards, pending, exec_meta = [], [], []
    for key, idx in partitions:
        td_mode, use_movement, use_dynamic, use_closed, with_trim = key
        use_bloom = td_mode == "bloom"
        can_demote = td_mode != "static"
        sub = [specs[i] for i in idx]
        # faults are traced per-drive DATA (rates/limits/seeds ride in
        # policy), deliberately NOT a _part_key dimension: a faulty drive
        # and a fault-free one share a compiled sub-batch, and the fault
        # machinery is traced in only when some drive of the sub-batch can
        # actually fail an erase — all-zero-rate sub-batches keep the
        # exact fault-free step structure (bit-identity, tests/test_faults)
        with_faults = any(s.mcfg.has_faults for s in sub)
        # group-cap padding is PER PARTITION: bloom filter width scales with
        # 1/max_groups, so padding a bloom drive beyond its sub-batch's own
        # cap would change its hashes vs the standalone managers.simulate
        g_max = max(s.mcfg.max_groups for s in sub)
        # per-drive interval lengths force the traced-h predicate (per-step
        # selects of the §5.1 machinery under vmap); homogeneous sub-batches
        # keep the scalar fast path
        per_drive_interval = (
            len({s.mcfg.interval_frac for s in sub}) > 1
        )
        sts, policies, page_rates, params, streams = [], [], [], [], []
        page_groups = []
        n_groups_max = 1
        for s in sub:
            st, n_groups, assumed_p, fdp_rate, rates, pg0 = build_drive(
                geom, s.mcfg, list(s.phases),
                init_p_from_phase=init_p_from_phase,
                g_max=g_max, use_bloom=use_bloom,
            )
            page_groups.append(pg0)
            n_groups_max = max(n_groups_max, n_groups)
            ctx_d = SimContext(
                geom, dataclasses.replace(s.mcfg, max_groups=g_max),
                n_groups, use_bloom=use_bloom,
                use_movement=use_movement, can_demote=can_demote,
                use_dynamic=use_dynamic, use_closed_alloc=use_closed,
                with_faults=with_faults,
            )
            policy = policy_from_config(ctx_d, assumed_p, fdp_rate)
            # the drive keeps its OWN dynamic-group cap in the padded arrays
            policy["max_groups"] = jnp.asarray(s.mcfg.max_groups, jnp.int32)
            sts.append(st)
            policies.append(policy)
            page_rates.append(
                np.concatenate(
                    [rates,
                     np.zeros((p_max - len(rates),) + rates.shape[1:],
                              rates.dtype)]
                )
            )
            params.append(
                phase_param_arrays(list(s.phases), g_max=g_wl, p_max=p_max)
            )
            if sampler == "numpy" and with_trim:
                # exact host op streams (Phase.sample_ops: pure-write
                # phases consume the draws Phase.sample would)
                rng = np.random.default_rng(s.seed)
                pairs = [ph.sample_ops(rng) for ph in s.phases]
                streams.append((
                    jnp.asarray(np.concatenate([o for o, _ in pairs]),
                                jnp.int32),
                    jnp.asarray(np.concatenate([l for _, l in pairs]),
                                jnp.int32),
                ))
            elif sampler == "numpy":
                rng = np.random.default_rng(s.seed)
                streams.append(
                    jnp.asarray(
                        np.concatenate([ph.sample(rng) for ph in s.phases]),
                        jnp.int32,
                    )
                )
            else:
                # key on the seed ALONE, mirroring the numpy sampler: a
                # drive's stream is a function of (phases, seed), never of
                # its position in the specs list (same seed + same phases
                # → common random numbers for paired policy comparisons)
                streams.append(jax.random.PRNGKey(s.seed))

        ctx = SimContext(
            geom,
            # the shared ctx keeps the SUB-BATCH's interval_frac so ctx.h
            # (the scalar predicate) is exact on the homogeneous fast path;
            # td_mode/movement/dynamic/alloc mirror the partition key (the
            # simulator dispatches the detector and the interval machinery
            # from these statics at trace time)
            dataclasses.replace(
                base, name="fleet", max_groups=g_max,
                interval_frac=sub[0].mcfg.interval_frac,
                movement_ops=use_movement, td_mode=td_mode,
                dynamic_groups=use_dynamic,
                alloc_mode=sub[0].mcfg.alloc_mode,
                # normalize per-drive fault knobs out of the shared ctx:
                # rates/limits/seeds are traced policy data, so the memoized
                # runner key must depend only on with_faults (structure) and
                # erase_max_retries (shared static), never on which rates
                # this particular fleet happens to sweep
                fault_rate=0.0, fault_rate_worn=1.0,
                endurance_pe_limit=0, spare_blocks=None, fault_seed=0,
            ),
            n_groups_max,
            use_bloom=use_bloom,
            gc_impl=gc_impl,
            per_drive_interval=per_drive_interval,
            fast_path=fast_path,
            use_movement=use_movement,
            can_demote=can_demote,
            use_dynamic=use_dynamic,
            use_closed_alloc=use_closed,
            trace_every=trace_every,
            unroll=unroll,
            with_trim=with_trim,
            with_faults=with_faults,
        )
        args = (
            _stack(sts),
            _stack(streams),
            {k: jnp.asarray(np.stack([p[k] for p in params]))
             for k in params[0]},
            jnp.asarray(np.stack(page_rates)),
            jnp.asarray(np.stack(page_groups)),
            _stack(policies),
        )
        # mesh dispatch: every sub-batch uses the full resolved device
        # count; raggedness is absorbed by inert filler drives (per-device
        # wall-clock is ceil(B/d) scans with or without the pad). Dispatch
        # is async — the runner call returns once enqueued, so the next
        # iteration's host-side build_drive/stacking overlaps this
        # sub-batch's device execution (the pipeline).
        d = min(n_dev, len(sub))
        pad = (-len(sub)) % d
        if pad:
            args = pad_batch(args, pad)
        runner = subbatch_runner(ctx, n_total, sampler == "jax", d)
        pending.append((key, idx, runner(*args), pad))
        exec_meta.append({"drives": len(sub), "devices": d, "padding": pad})

    # resolve pass: block on each sub-batch's outputs (host↔device transfer
    # happens here, after every sub-batch has been enqueued) and strip the
    # filler rows so padding never surfaces. Resolution is fenced PER
    # sub-batch: a failure (device OOM, a poisoned buffer, a runtime error
    # deferred by async dispatch) is recorded with its sub-batch index,
    # partition key, and drive ids, and the REMAINING sub-batches still
    # resolve — one bad sub-batch no longer orphans the others' already-
    # dispatched work or surfaces as a context-free traceback.
    failures: list[SubbatchFailure] = []
    for k_i, (key, idx, out, pad) in enumerate(pending):
        try:
            st_f, trace, lbas = out
            b = len(idx)
            app[idx], mig[idx] = (
                np.asarray(trace[0][:b]), np.asarray(trace[1][:b])
            )
            if return_lbas:
                lbas_out[idx] = np.asarray(lbas[:b])
            if pad:
                st_f = jax.tree_util.tree_map(lambda a: a[:b], st_f)
            shards.append((idx, st_f))
        except Exception as e:  # noqa: BLE001 — rewrapped with context below
            failures.append(SubbatchFailure(
                subbatch=k_i, part_key=key, drive_ids=tuple(idx),
                labels=tuple(specs[i].label for i in idx), error=e,
            ))
    if failures:
        raise SubbatchResolutionError(failures, n_subbatches=len(pending))

    return FleetResult(
        app=app, mig=mig, specs=list(specs), shards=shards, lbas=lbas_out,
        geom=geom, trace_every=trace_every, exec_meta=exec_meta,
    )
