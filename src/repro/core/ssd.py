"""SSD geometry + simulator state (paper §3 system model).

The simulator is WRITE-AMPLIFICATION-faithful, not timing-faithful: every
figure in the paper reports WA (migrations per application write), which is
what we reproduce. Consequences (documented in DESIGN.md):

  * LUNs are kept as a static label (they set Wolf's F = LUNs·B minimum group
    size) but placement/victim search are pool-global — per-LUN victim search
    changes victim-search COST, not WA (§5.4).
  * channel timing / virtual time is out of scope.

State is a :class:`SimState` — a frozen dataclass registered as a JAX
pytree, so the whole simulator jits, vmaps, checkpoints, and scans. The
logical→physical mapping is ONE packed int32 array (``page_map``,
``blk * pages_per_block + slot``, ``-1`` = unmapped), so every lookup,
invalidate, and write touches a single gather/scatter instead of two.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

FREE, OPEN, CLOSED = 0, 1, 2
# terminal block state: an erase failed past its retry budget and the block
# was pulled from circulation. A RETIRED block KEEPS its group_of label (so
# the per-group retired accounting survives §5.2 merges) but is excluded
# from every FREE/CLOSED mask — it can never be claimed, written, or
# selected as a GC victim again.
RETIRED = 3
# traced drive_status values: a drive whose spare pool is exhausted flips
# to DEGRADED (read-only/halted — every subsequent op freezes as a no-op)
# instead of violating the pool invariants.
STATUS_OK, STATUS_DEGRADED = 0, 1
INT32_MAX = 2**31 - 1


def surplus_of(grp_active, grp_phys, grp_alloc):
    """Masked per-group block surplus (the carried ``SimState.grp_surplus``).

    Inactive groups sit at -INT32_MAX so the movement-op argmax never picks
    them. Recomputed (an O(G) elementwise op) at every site that touches
    ``grp_phys``/``grp_alloc``/``grp_active`` rather than patched per index —
    G is tiny and one formula can't drift from the invariant.
    """
    return jnp.where(
        grp_active, grp_phys - grp_alloc, -INT32_MAX
    ).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Physical geometry. Defaults: a scaled-down Table-2 SSD (ratios kept)."""

    n_luns: int = 8
    blocks_per_lun: int = 64
    pages_per_block: int = 16
    lba_pba: float = 0.70

    @property
    def n_blocks(self) -> int:
        return self.n_luns * self.blocks_per_lun

    @property
    def pba_pages(self) -> int:
        return self.n_blocks * self.pages_per_block

    @property
    def lba_pages(self) -> int:
        return int(self.pba_pages * self.lba_pba)

    @property
    def op_pages(self) -> int:
        return self.pba_pages - self.lba_pages


# (α, β, γ, τ) victim-score weight points per gc_policy preset; see
# ManagerConfig.gc_weights. wear's β trades reclaim benefit (pages freed)
# against per-block P-E imbalance: 0.25 — a page of benefit per 4 cycles
# of wear skew — levels ~3× harder than greedy at single-digit-% WA cost;
# larger β overshoots (GC starts cleaning full cold blocks, churning
# erases faster than it levels them). Swept per-drive via gc_beta.
GC_WEIGHT_PRESETS = {
    "greedy": (1.0, 0.0, 0.0, 0.0),
    "lru": (0.0, 0.0, 1.0, 0.0),
    "wear": (1.0, 0.25, 0.0, 0.0),
    "trim_aware": (1.0, 0.0, 0.0, 1.0),
}


@dataclasses.dataclass(frozen=True)
class ManagerConfig:
    """Block-manager policy knobs. Presets in core/managers.py."""

    name: str = "wolf"
    max_groups: int = 8
    # over-provisioning allocation: wolf | fdp_assumed | size | freq |
    # optimal | single
    alloc_mode: str = "wolf"
    # victim-selection preset: greedy | lru | wear | trim_aware. Resolved by
    # :meth:`gc_weights` into the traced (α, β, γ, τ) score weights; the
    # explicit gc_* float fields below override individual components.
    gc_policy: str = "greedy"
    # multi-objective victim-score weights (None → take from the gc_policy
    # preset). The score, maximised over CLOSED blocks of the GC group:
    #   S(blk) = α·(B − live)  − γ·stamp  − β·erase_count  − τ·trim_dead
    # α: reclaim benefit, γ: migration-cost/recency (LRU), β: wear-leveling,
    # τ: trim-awareness (deprioritise blocks rich in trimmed-but-unerased
    # slots). These are per-drive TRACED data in the fleet runner — a batch
    # sweeps the weight space in one compiled grid.
    gc_alpha: float | None = None
    gc_beta: float | None = None
    gc_gamma: float | None = None
    gc_trim_penalty: float | None = None
    movement_ops: bool = True
    # temperature detection / page targeting:
    #   static  — page stays in its (workload-defined) group  [Wolf+oracle]
    #   fdp     — promote on update / demote on GC vs fixed assumed bands
    #   bloom   — two bloom filters per group (paper §5.6)
    td_mode: str = "static"
    dynamic_groups: bool = False  # create/merge groups (paper §5.2)
    # paper constants; interval_frac and ewma_a are lowered into the traced
    # per-drive policy pytree (fleet drives may sweep them — §5.1 knobs)
    interval_frac: float = 0.001  # h = LBA · 0.001
    ewma_a: float = 0.3
    q_create: float = 2.0
    w_intervals: int = 50
    cold_hit_rate_frac: float = 0.05
    cold_op_frac: float = 0.05
    gc_reserve_blocks: int = 2
    bloom_bits_per_page: int = 4
    # emergency-valve bound: max global greedy reclaims per write when the
    # pool is (nearly) empty (simulator.make_step's while_loop)
    valve_max_tries: int = 4
    # §5.6 bloom rotation floor: a group's filter pair rotates every
    # max(grp_size, this) writes, so tiny/fresh groups don't thrash
    bloom_rotate_min_writes: int = 64
    # -- fault injection / bad-block retirement (simulator erase sites) ----
    # Per-erase Bernoulli failure probability. A failed erase retries up to
    # erase_max_retries times; a block whose retries all fail is RETIRED
    # and replaced from the spare pool. fault_rate and the endurance knobs
    # are TRACED per-drive policy data — fleets sweep failure rates ×
    # endurance limits in one compiled grid (no step-structure change).
    fault_rate: float = 0.0
    # failure probability once a block's erase_count crosses the endurance
    # limit (the worn regime). Default 1.0: a block dies deterministically
    # at its P-E limit, the classic endurance-budget model — a worn rate
    # < 1 models the softer exponential tail instead.
    fault_rate_worn: float = 1.0
    # per-block P-E endurance limit; 0 disables the worn regime entirely
    endurance_pe_limit: int = 0
    # retry budget before a failing erase retires its block (shared static:
    # it shapes the retire probability rate^(1+retries), not the trace)
    erase_max_retries: int = 3
    # spare-block pool size; None = every physical block beyond the logical
    # content + GC reserve + group slots (the init_state auto bound). When
    # the pool exhausts, the next retirement flips drive_status to DEGRADED.
    spare_blocks: int | None = None
    # per-drive fault stream seed (traced policy data, like fault_rate)
    fault_seed: int = 0

    @property
    def has_faults(self) -> bool:
        """True iff this config can ever fail an erase — the fleet layer
        derives ``SimContext.with_faults`` (per sub-batch) from this."""
        return self.fault_rate > 0.0 or (
            self.endurance_pe_limit > 0 and self.fault_rate_worn > 0.0
        )

    def gc_weights(self) -> tuple:
        """Resolve the victim-score weights (α, β, γ, τ) for this drive.

        Starts from the :data:`GC_WEIGHT_PRESETS` entry for ``gc_policy``;
        any explicitly-set ``gc_alpha``/``gc_beta``/``gc_gamma``/
        ``gc_trim_penalty`` overrides its component. The legacy policies are
        exact weight points: greedy = (1,0,0,0) maximises ``B − live`` ≡
        minimises ``live``; lru = (0,0,1,0) minimises ``stamp`` — both with
        the same first-index tie-break as the old argmin branch.
        """
        base = GC_WEIGHT_PRESETS[self.gc_policy]
        over = (self.gc_alpha, self.gc_beta, self.gc_gamma,
                self.gc_trim_penalty)
        return tuple(
            float(b if o is None else o) for b, o in zip(base, over)
        )


def bloom_bits(geom: Geometry, mcfg: ManagerConfig) -> int:
    """Bits per group-filter for the §5.6 bloom detector pair."""
    return max(
        64, geom.lba_pages * mcfg.bloom_bits_per_page // mcfg.max_groups
    )


_SIM_STATE_FIELDS = (
    # page mapping (packed: blk * pages_per_block + slot, -1 = unmapped)
    "page_map",
    # block state
    "slot_lba", "valid", "live", "fill", "stamp", "state", "group_of",
    # wear / endurance (per-block P-E counts + O(1) carried aggregates)
    "erase_count", "trim_dead", "erase_total", "erase_sq_total",
    # per-group
    "active_blk", "grp_size", "grp_phys", "grp_p", "grp_writes",
    "grp_alloc", "grp_active", "grp_created", "grp_surplus", "grp_live",
    # O(1) accounting (incrementally maintained; see check_invariants)
    "free_blocks", "mapped_pages",
    # fault / retirement layer (bad-block management; see simulator.py)
    "retired_blocks", "spares_left", "grp_retired", "drive_status",
    "degraded_at", "n_erase_fail", "n_halted", "fault_draws",
    # detector (bloom filter pair)
    "bloom_active", "bloom_passive", "bloom_writes",
    # counters
    "n_app", "n_mig", "n_erase", "n_dropped", "n_trim", "clock",
    "interval", "cooldown",
)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=list(_SIM_STATE_FIELDS),
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class SimState:
    """Full drive state: a frozen, pytree-registered bundle of jnp arrays.

    Immutable by construction — state-mutating helpers build the successor
    state with :meth:`replace` (no ``dict(st)`` copies). Mapping-style read
    access (``st["live"]``, ``.items()``) is kept for analysis/tests code
    that iterates fields generically.
    """

    page_map: jax.Array  # [LBA] int32 packed physical address, -1 unmapped
    slot_lba: jax.Array  # [K, B] int32 lba living in each slot, -1 empty
    valid: jax.Array     # [K, B] bool
    live: jax.Array      # [K] int32 live pages per block
    fill: jax.Array      # [K] int32 written slots per block
    stamp: jax.Array     # [K] int32 LRU age (claim-time clock)
    state: jax.Array     # [K] int8 FREE/OPEN/CLOSED
    group_of: jax.Array  # [K] int32 owning group, -1 = none
    # wear/endurance layer: every erase site bumps erase_count[victim] and
    # the two carried aggregates (cross-checked in check_invariants), so
    # variance/imbalance analytics are O(1) reads, never reductions
    erase_count: jax.Array  # [K] int32 per-block P-E (erase) cycles
    # trimmed-but-unerased slots per block: +1 when a TRIM invalidates a
    # mapping in the block, reset to 0 when the block is erased. Feeds the
    # τ term of the victim score; always ≤ fill − live (dead slots)
    trim_dead: jax.Array  # [K] int32
    erase_total: jax.Array     # [] int32 == Σ erase_count == n_erase
    erase_sq_total: jax.Array  # [] int32 == Σ erase_count² (for variance)
    active_blk: jax.Array   # [G] int32 open block per group, -1 = none
    grp_size: jax.Array     # [G] int32 logical pages per group
    grp_phys: jax.Array     # [G] int32 physical blocks per group
    grp_p: jax.Array        # [G] float32 EWMA update frequency
    grp_writes: jax.Array   # [G] int32 writes this interval
    grp_alloc: jax.Array    # [G] int32 block budget (§5.5)
    grp_active: jax.Array   # [G] bool
    grp_created: jax.Array  # [G] int32 creation interval
    # carried block-surplus per group: grp_phys - grp_alloc where active,
    # -INT_MAX elsewhere — the movement-op argmax reads this directly
    grp_surplus: jax.Array  # [G] int32
    # carried per-group mapped-page count: == Σ live over the group's
    # blocks always, and — because group membership IS residence, so a
    # trimmed page belongs to no group — equal to ``grp_size`` by
    # construction (every mutation site applies the same delta to both).
    # Carried separately so the effective-size consumers (§5.5 allocator,
    # detector hit rates, fleet analytics) name the utilization counter
    # the TRIM model is stated in (Frankie et al., arXiv:1208.1794:
    # trimmed space is dynamic over-provisioning), and so
    # ``check_invariants`` cross-checks both update chains against the
    # ground truth independently.
    grp_live: jax.Array  # [G] int32
    # incrementally-maintained pool size: == (state == FREE).sum() always.
    # Every per-write predicate (GC low-pool, emergency valve, movement-op
    # headroom) is an O(1) read of this scalar; the only surviving full
    # reductions over block state are per-GC (victim search) or diagnostic
    # (check_invariants).
    free_blocks: jax.Array  # [] int32
    # incrementally-maintained drive utilization: == (page_map >= 0).sum()
    # always. TRIM decrements it, a write of an unmapped page increments
    # it; the effective-OP analytics (core/analytics.effective_op_ratio,
    # FleetResult.predicted_wa) read this scalar instead of reducing over
    # the logical span.
    mapped_pages: jax.Array  # [] int32
    # -- fault / retirement layer (bad-block management) --------------------
    # O(1) carried retirement accounting, cross-checked by check_invariants:
    # retired_blocks == (state == RETIRED).sum(); grp_retired[g] == retired
    # blocks still labeled group g (RETIRED blocks keep group_of, so the
    # counts relabel consistently through §5.2 merges); spares_left is the
    # remaining spare-block budget (each retirement draws one; at 0 the
    # NEXT retirement degrades the drive instead).
    retired_blocks: jax.Array  # [] int32 == (state == RETIRED).sum()
    spares_left: jax.Array     # [] int32 ≥ 0 always
    grp_retired: jax.Array     # [G] int32 retired blocks per group label
    # STATUS_OK until a retirement finds the spare pool empty, then
    # STATUS_DEGRADED forever: every subsequent op freezes as a no-op
    # (an inert lane under vmap — the fleet masks it like a filler drive)
    drive_status: jax.Array  # [] int32 STATUS_OK / STATUS_DEGRADED
    degraded_at: jax.Array   # [] int32 n_app at degradation, -1 = alive
    n_erase_fail: jax.Array  # [] int32 failed erase attempts (incl. retired)
    n_halted: jax.Array      # [] int32 ops frozen after degradation
    fault_draws: jax.Array   # [] uint32 fault-stream counter (hash input)
    bloom_active: jax.Array   # [G, bits] bool (§5.6); [G, 1] when unused
    bloom_passive: jax.Array  # [G, bits] bool
    bloom_writes: jax.Array   # [G] int32
    n_app: jax.Array      # [] int32 application writes
    n_mig: jax.Array      # [] int32 GC migrations
    n_erase: jax.Array    # [] int32 block erases
    n_dropped: jax.Array  # [] int32 dropped writes (pool exhausted; tested 0)
    n_trim: jax.Array     # [] int32 TRIM ops processed (incl. no-op re-trims)
    clock: jax.Array      # [] int32 block-claim clock (LRU)
    interval: jax.Array   # [] int32 completed §5.1 intervals
    cooldown: jax.Array   # [] int32 intervals until create/merge allowed

    def replace(self, **updates) -> "SimState":
        return dataclasses.replace(self, **updates)

    # -- read-only mapping conveniences (analysis / generic test code) ------
    def __getitem__(self, key: str) -> jax.Array:
        return getattr(self, key)

    def keys(self):
        return iter(_SIM_STATE_FIELDS)

    def items(self):
        return ((k, getattr(self, k)) for k in _SIM_STATE_FIELDS)

    # -- diagnostics --------------------------------------------------------
    def check_invariants(self) -> dict:
        """Full-reduction cross-checks of the O(1)/O(G) carried accounting.

        Returns a dict of named boolean jnp scalars (jit/vmap-friendly);
        :func:`assert_invariants` is the host-side raising wrapper. This is
        the ONLY place outside victim selection that still reduces over the
        whole block array — by design: the write path reads the carried
        scalars, and this checker proves they never drift.
        """
        k, b = self.slot_lba.shape
        arange_g = jnp.arange(self.grp_active.shape[0])
        # per-group physical block counts from scratch. A RETIRED block
        # keeps its group label for grp_retired accounting but is out of
        # circulation — grp_phys counts only OPEN/CLOSED blocks.
        owned = self.group_of[None, :] == arange_g[:, None]  # [G, K]
        in_service = (self.state[None, :] == OPEN) | (
            self.state[None, :] == CLOSED
        )
        phys = jnp.sum(owned & in_service, axis=1)
        # packed-map injectivity: every mapped lba names a distinct, valid
        # slot whose slot_lba points back at it
        pm = self.page_map
        mapped = pm >= 0
        pm_c = jnp.where(mapped, pm, k * b)
        hits = jnp.zeros(k * b + 1, jnp.int32).at[pm_c].add(1)
        back = jnp.where(
            mapped,
            self.slot_lba.reshape(-1)[jnp.minimum(pm_c, k * b - 1)]
            == jnp.arange(pm.shape[0]),
            True,
        )
        slot_valid = jnp.where(
            mapped,
            self.valid.reshape(-1)[jnp.minimum(pm_c, k * b - 1)],
            True,
        )
        return {
            "free_blocks": self.free_blocks == jnp.sum(self.state == FREE),
            "grp_phys": jnp.all(phys == self.grp_phys),
            "grp_surplus": jnp.all(
                self.grp_surplus
                == surplus_of(self.grp_active, self.grp_phys, self.grp_alloc)
            ),
            "grp_size": jnp.all(
                jnp.sum(
                    owned * self.live[None, :], axis=1
                ) == self.grp_size
            ),
            "grp_live": jnp.all(
                jnp.sum(
                    owned * self.live[None, :], axis=1
                ) == self.grp_live
            ),
            "mapped_pages": self.mapped_pages == jnp.sum(mapped),
            "page_map_injective": jnp.all(hits[: k * b] <= 1),
            "page_map_valid": jnp.all(slot_valid),
            "page_map_backptr": jnp.all(back),
            "live_counts": jnp.all(
                jnp.sum(self.valid, axis=1) == self.live
            ),
            "fill_bounds": jnp.all(
                (self.fill >= self.live) & (self.fill <= b)
            ),
            # wear accounting: the carried aggregates equal the reductions,
            # the per-block counters never go negative, and every erase
            # bumped exactly one block (Σ erase_count == n_erase)
            "erase_conservation": (
                (self.erase_total == jnp.sum(self.erase_count))
                & (self.erase_total == self.n_erase)
            ),
            "erase_sq_total": self.erase_sq_total
            == jnp.sum(self.erase_count * self.erase_count),
            "erase_nonneg": jnp.all(self.erase_count >= 0),
            # trim_dead counts a subset of each block's dead slots and is
            # cleared by erase — FREE blocks (fill == 0) sit at 0
            "trim_dead_bounds": jnp.all(
                (self.trim_dead >= 0)
                & (self.trim_dead <= self.fill - self.live)
            ),
            "trim_dead_pure_write": (self.n_trim > 0)
            | jnp.all(self.trim_dead == 0),
            # fault / retirement accounting: the carried counters equal the
            # reductions, the spare pool never goes negative, and a
            # degraded drive has a recorded degradation time
            "retired_blocks": self.retired_blocks
            == jnp.sum(self.state == RETIRED),
            "grp_retired": jnp.all(
                jnp.sum(owned & (self.state[None, :] == RETIRED), axis=1)
                == self.grp_retired
            ),
            "spares_nonneg": self.spares_left >= 0,
            "degraded_consistent": (self.drive_status == STATUS_OK)
            | (self.degraded_at >= 0),
        }


def assert_invariants(st: SimState, label: str = "") -> None:
    """Host-side :meth:`SimState.check_invariants` with named failures."""
    failed = [k for k, ok in st.check_invariants().items() if not bool(ok)]
    assert not failed, f"invariants violated{f' ({label})' if label else ''}: {failed}"


def init_state(
    geom: Geometry,
    mcfg: ManagerConfig,
    page_group,
    n_groups: int,
    use_bloom: bool = True,
) -> SimState:
    """Build a pre-conditioned (fully mapped) drive.

    page_group: int array [LBA] — initial group of every logical page.
    Pages are laid out group-contiguously; leftover blocks are FREE.
    """
    import numpy as np

    k, b, lba = geom.n_blocks, geom.pages_per_block, geom.lba_pages
    g_max = mcfg.max_groups
    page_group = np.asarray(page_group, np.int32)
    assert page_group.shape == (lba,)
    assert page_group.max() < n_groups <= g_max

    order = np.argsort(page_group, kind="stable")  # group-contiguous layout
    page_map = np.full(lba, -1, np.int32)
    slot_lba = np.full((k, b), -1, np.int32)
    valid = np.zeros((k, b), bool)
    live = np.zeros(k, np.int32)
    fill = np.zeros(k, np.int32)
    group_of = np.full(k, -1, np.int32)
    state_arr = np.zeros(k, np.int8)

    blk = 0
    slot = 0
    prev_g = int(page_group[order[0]])
    for idx in order:
        g = int(page_group[idx])
        if g != prev_g and slot > 0:  # group boundary → new block
            blk += 1
            slot = 0
            prev_g = g
        if slot == 0:
            group_of[blk] = g
            state_arr[blk] = CLOSED
        page_map[idx] = blk * b + slot
        slot_lba[blk, slot] = idx
        valid[blk, slot] = True
        slot += 1
        if slot == b:
            blk += 1
            slot = 0
    if slot > 0:
        blk += 1
    # fill levels / live counts
    for j in range(blk):
        live[j] = valid[j].sum()
        fill[j] = b if state_arr[j] == CLOSED else valid[j].sum()
    fill[:blk] = b  # partially-filled tail blocks are sealed CLOSED
    state_arr[:blk] = CLOSED

    grp_size = np.bincount(page_group, minlength=g_max).astype(np.int32)
    grp_phys = np.bincount(group_of[group_of >= 0], minlength=g_max).astype(np.int32)
    grp_active = np.zeros(g_max, bool)
    grp_active[:n_groups] = True

    # spare-block pool: at most the physical blocks beyond the logical
    # content, the GC reserve, one active block per group slot, and two
    # blocks of migration headroom — retiring more than this would leave
    # the allocator with no usable over-provisioning. mcfg.spare_blocks
    # clamps WITHIN that bound (None = take it all).
    content_blocks = -(-lba // b)  # ceil
    auto_spares = max(
        0, k - content_blocks - mcfg.gc_reserve_blocks - g_max - 2
    )
    spares = (
        auto_spares
        if mcfg.spare_blocks is None
        else max(0, min(mcfg.spare_blocks, auto_spares))
    )

    return SimState(
        page_map=jnp.asarray(page_map),
        slot_lba=jnp.asarray(slot_lba),
        valid=jnp.asarray(valid),
        live=jnp.asarray(live),
        fill=jnp.asarray(fill),
        # LRU ages: initially-filled blocks aged by layout order (see
        # simulator._pop_free_block for the claim-time clock)
        stamp=jnp.asarray(
            np.where(np.arange(k) < blk, np.arange(k), 0).astype(np.int32)
        ),
        state=jnp.asarray(state_arr),
        group_of=jnp.asarray(group_of),
        erase_count=jnp.zeros(k, jnp.int32),
        trim_dead=jnp.zeros(k, jnp.int32),
        erase_total=jnp.zeros((), jnp.int32),
        erase_sq_total=jnp.zeros((), jnp.int32),
        active_blk=jnp.full(g_max, -1, jnp.int32),
        grp_size=jnp.asarray(grp_size),
        grp_phys=jnp.asarray(grp_phys),
        grp_p=jnp.zeros(g_max, jnp.float32),
        grp_writes=jnp.zeros(g_max, jnp.int32),
        grp_alloc=jnp.asarray(np.maximum(grp_phys, 1)),
        grp_active=jnp.asarray(grp_active),
        grp_created=jnp.zeros(g_max, jnp.int32),
        grp_surplus=jnp.asarray(
            np.where(
                grp_active, grp_phys - np.maximum(grp_phys, 1), -INT32_MAX
            ).astype(np.int32)
        ),
        grp_live=jnp.asarray(grp_size),  # fully mapped: live == size
        free_blocks=jnp.asarray(int((state_arr == FREE).sum()), jnp.int32),
        mapped_pages=jnp.asarray(lba, jnp.int32),
        retired_blocks=jnp.zeros((), jnp.int32),
        spares_left=jnp.asarray(spares, jnp.int32),
        grp_retired=jnp.zeros(g_max, jnp.int32),
        drive_status=jnp.asarray(STATUS_OK, jnp.int32),
        degraded_at=jnp.asarray(-1, jnp.int32),
        n_erase_fail=jnp.zeros((), jnp.int32),
        n_halted=jnp.zeros((), jnp.int32),
        fault_draws=jnp.zeros((), jnp.uint32),
        # (G, 1) placeholder when the context excludes the bloom branch
        # (SimContext.use_bloom=False)
        bloom_active=jnp.zeros(
            (g_max, bloom_bits(geom, mcfg) if use_bloom else 1), bool
        ),
        bloom_passive=jnp.zeros(
            (g_max, bloom_bits(geom, mcfg) if use_bloom else 1), bool
        ),
        bloom_writes=jnp.zeros(g_max, jnp.int32),
        n_app=jnp.zeros((), jnp.int32),
        n_mig=jnp.zeros((), jnp.int32),
        n_erase=jnp.zeros((), jnp.int32),
        n_dropped=jnp.zeros((), jnp.int32),
        n_trim=jnp.zeros((), jnp.int32),
        clock=jnp.asarray(blk, jnp.int32),
        interval=jnp.zeros((), jnp.int32),
        cooldown=jnp.zeros((), jnp.int32),
    )
