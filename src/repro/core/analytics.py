"""Analytical write-amplification model (paper §4 + Appendix A).

All functions are pure jnp and jit/vmap/grad-compatible. They operate on
float arrays of any shape (broadcasting elementwise).

Notation (paper Table 1):
    B    pages per erase block
    LBA  logical address space, in pages
    PBA  physical address space, in pages
    OP   over-provisioned pages, OP = PBA - LBA
    r    the over-provisioning ratio LBA/PBA in (0, 1)
    delta (δ)  mean fraction of a victim block's pages migrated per GC
    WA   write-amplification = physical writes / application writes

Key results reproduced here:
    eq. (1)  X = LBA * ln(B / G)        (updates until G live pages remain)
    eq. (2)  G = B * exp(-X / LBA)      (block decay)
    eq. (3)  r = (δ - 1) / ln(δ)        (equilibrium)
    WA       = 1 / (1 - δ)
    eq. (9)  δ = -r * W0(-(1/r) e^(-1/r))   (Appendix A, Lambert-W inverse)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "block_decay_updates",
    "block_live_pages",
    "op_ratio_from_delta",
    "delta_from_op_ratio",
    "delta_from_op_ratio_lambertw",
    "wa_from_delta",
    "delta_from_wa",
    "wa_from_op_ratio",
    "op_ratio_from_wa",
    "effective_op_ratio",
    "wa_with_trim",
    "lambertw0",
    "wear_variance",
    "wear_imbalance",
    "lifetime_host_writes",
    "dwpd_from_lifetime",
    "retired_fraction",
    "degraded_op_ratio",
    "wa_with_retirement",
    "survival_fraction",
    "wa_vs_lifetime",
]


# ---------------------------------------------------------------------------
# Block lifetime (paper §4.1)
# ---------------------------------------------------------------------------

def block_decay_updates(g: jax.Array, *, b: float, lba: float) -> jax.Array:
    """Eq. (1): expected application updates X until a freshly written block of
    ``b`` pages has decayed to ``g`` live pages, under a uniform workload over
    ``lba`` logical pages."""
    g = jnp.asarray(g)
    return lba * jnp.log(b / g)


def block_live_pages(x: jax.Array, *, b: float, lba: float) -> jax.Array:
    """Eq. (2): expected live pages G remaining after ``x`` application updates."""
    x = jnp.asarray(x)
    return b * jnp.exp(-x / lba)


# ---------------------------------------------------------------------------
# Equilibrium (paper §4.2)
# ---------------------------------------------------------------------------

def op_ratio_from_delta(delta: jax.Array) -> jax.Array:
    """Eq. (3): LBA/PBA as a function of δ.

    (δ-1)/ln(δ) is smooth on (0,1) with a removable singularity at δ=1 where
    the value tends to 1 (full utilization). We guard δ→1 and δ→0.
    """
    delta = jnp.asarray(delta)
    eps = jnp.asarray(1e-12, delta.dtype)
    d = jnp.clip(delta, eps, 1.0 - 1e-7)
    return (d - 1.0) / jnp.log(d)


def wa_from_delta(delta: jax.Array) -> jax.Array:
    """WA = 1/(1-δ) (paper §4.2)."""
    delta = jnp.asarray(delta)
    return 1.0 / (1.0 - delta)


def delta_from_wa(wa: jax.Array) -> jax.Array:
    """Inverse of ``wa_from_delta``: δ = 1 - 1/WA."""
    wa = jnp.asarray(wa)
    return 1.0 - 1.0 / wa


def delta_from_op_ratio(r: jax.Array, *, iters: int = 80) -> jax.Array:
    """Invert eq. (3): given r = LBA/PBA in (0,1), find δ in (0,1) with
    (δ-1)/ln(δ) = r.

    f(δ) = (δ-1)/ln(δ) is strictly increasing on (0,1) with range (0,1), so a
    fixed-count bisection converges to machine precision and is jit-friendly
    (no data-dependent control flow).
    """
    r = jnp.asarray(r)
    dtype = jnp.result_type(r, jnp.float32)
    lo = jnp.full(jnp.shape(r), 1e-9, dtype)
    hi = jnp.full(jnp.shape(r), 1.0 - 1e-9, dtype)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        too_low = op_ratio_from_delta(mid) < r  # need bigger δ
        lo = jnp.where(too_low, mid, lo)
        hi = jnp.where(too_low, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def wa_from_op_ratio(r: jax.Array, *, iters: int = 80) -> jax.Array:
    """WA at equilibrium for a uniform workload with over-provisioning ratio r."""
    return wa_from_delta(delta_from_op_ratio(r, iters=iters))


def op_ratio_from_wa(wa: jax.Array) -> jax.Array:
    """r = LBA/PBA needed to hit a target equilibrium WA (closed form via eq. 3)."""
    return op_ratio_from_delta(delta_from_wa(wa))


# ---------------------------------------------------------------------------
# TRIM as dynamic over-provisioning (Frankie et al., arXiv:1208.1794;
# object-based variant arXiv:1210.5975)
# ---------------------------------------------------------------------------

def effective_op_ratio(r: jax.Array, trim_frac: jax.Array) -> jax.Array:
    """Effective utilization ratio when a fraction ``trim_frac`` of the
    logical span is held TRIMMED.

    A trimmed page occupies no physical slot, so the drive's live content
    shrinks to (1 - t)·LBA while PBA is unchanged — the freed span is
    indistinguishable from factory over-provisioning to the GC:

        r_eff = (1 - t)·LBA / PBA = r·(1 - t)
        OP_eff = PBA - (1 - t)·LBA = OP + t·LBA

    Compose with :func:`wa_from_op_ratio` for the equilibrium WA at a
    given steady-state trim fraction (or use :func:`wa_with_trim`).
    Broadcasting elementwise, like every function in this module, so a
    whole utilization × trim-rate grid evaluates in one call.
    """
    r = jnp.asarray(r)
    trim_frac = jnp.asarray(trim_frac)
    return r * (1.0 - trim_frac)


def wa_with_trim(r: jax.Array, trim_frac: jax.Array, *,
                 iters: int = 80) -> jax.Array:
    """Equilibrium WA of a uniform workload at utilization ``r`` holding a
    ``trim_frac`` fraction of the logical span trimmed: eq. 3 evaluated at
    the Frankie effective OP ratio."""
    return wa_from_op_ratio(effective_op_ratio(r, trim_frac), iters=iters)


# ---------------------------------------------------------------------------
# Wear / endurance (per-block P-E counts → device-lifetime projections).
#
# The simulator carries erase_count ([K] P-E cycles per block) plus the O(1)
# aggregates erase_total (Σe) and erase_sq_total (Σe²), so these reduce to
# arithmetic on three scalars — no block-array reduction at analysis time.
# Endurance is a first-order design constraint alongside WA (Dubeyko,
# arXiv:1907.11825); GC strategy trades migration cost against it (Nagel et
# al., arXiv:1807.09313) — the (α, β, γ, τ) victim score sweeps that
# trade-off and these functions score its endurance side.
# ---------------------------------------------------------------------------

def wear_variance(erase_total: jax.Array, erase_sq_total: jax.Array,
                  n_blocks: int) -> jax.Array:
    """Population variance of per-block erase counts from the carried
    aggregates: Var[e] = Σe²/K − (Σe/K)²."""
    n = jnp.asarray(n_blocks, jnp.float32)
    mean = jnp.asarray(erase_total, jnp.float32) / n
    return jnp.asarray(erase_sq_total, jnp.float32) / n - mean * mean


def wear_imbalance(erase_count: jax.Array) -> jax.Array:
    """Max/mean P-E ratio over the block array (1.0 = perfectly level).

    The device dies when its WORST block exhausts its P-E budget, so the
    usable endurance of an unlevel drive scales down by this factor. Takes
    the [K] array (one reduction — analysis-time only); guarded for the
    zero-erase start-of-life state.
    """
    e = jnp.asarray(erase_count, jnp.float32)
    mean = jnp.mean(e)
    return jnp.where(mean > 0.0, jnp.max(e) / jnp.maximum(mean, 1e-12), 1.0)


def lifetime_host_writes(*, n_blocks: int, pages_per_block: int,
                         pe_cycles: float, wa: jax.Array,
                         imbalance: jax.Array) -> jax.Array:
    """Total host writes (in pages) until the worst block exhausts its P-E
    budget, given the drive's measured WA and wear imbalance.

    Each erase rewrites one block of B pages, so physical page writes per
    block-lifetime budget are K·B·PE. Host writes get WA× amplified, and an
    unlevel drive burns out when its hottest block — erased ``imbalance``×
    the mean rate — hits PE:

        host_pages = K · B · PE / (WA · imbalance)
    """
    phys_budget = jnp.asarray(
        n_blocks * pages_per_block * pe_cycles, jnp.float32
    )
    return phys_budget / (
        jnp.asarray(wa, jnp.float32)
        * jnp.maximum(jnp.asarray(imbalance, jnp.float32), 1.0)
    )


def dwpd_from_lifetime(host_pages: jax.Array, *, lba_pages: int,
                       years: float = 5.0) -> jax.Array:
    """Drive-writes-per-day sustainable over a ``years`` warranty window.

    host_pages / lba_pages = total full-drive writes (TBW in units of the
    logical capacity); divide by the window's days for DWPD.
    """
    days = jnp.asarray(years * 365.0, jnp.float32)
    return jnp.asarray(host_pages, jnp.float32) / (
        jnp.asarray(lba_pages, jnp.float32) * days
    )


# ---------------------------------------------------------------------------
# Survival / retirement (fault-injection layer: blocks wear out, retire,
# and shrink the OP the allocator divides — the WA-vs-lifetime study).
# TRIM's effective-OP algebra runs in reverse here: where a trimmed page
# ADDS dynamic over-provisioning, a retired block REMOVES physical space,
# so r_eff rises toward 1 and the eq. 3 equilibrium WA climbs as the
# drive ages (Dubeyko, arXiv:1907.11825 frames endurance management as
# exactly this capacity/lifetime trade).
# ---------------------------------------------------------------------------

def retired_fraction(retired_blocks: jax.Array,
                     n_blocks: int) -> jax.Array:
    """Fraction of the physical block array in the terminal RETIRED state
    (the simulator's O(1) carried ``retired_blocks`` over K)."""
    return jnp.asarray(retired_blocks, jnp.float32) / jnp.asarray(
        n_blocks, jnp.float32
    )


def degraded_op_ratio(r: jax.Array, retired_frac: jax.Array) -> jax.Array:
    """Effective utilization ratio of an aged drive: retirements shrink
    PBA while the logical span is unchanged,

        r_eff = LBA / (PBA·(1 - f)) = r / (1 - f)

    for retired fraction ``f`` — the mirror image of
    :func:`effective_op_ratio` (TRIM grows OP; retirement eats it).
    Clipped below 1 so the eq. 3 inversion stays defined at the point
    where retirement has consumed the entire OP (WA → ∞)."""
    r = jnp.asarray(r)
    f = jnp.asarray(retired_frac)
    return jnp.minimum(r / jnp.maximum(1.0 - f, 1e-9), 1.0 - 1e-7)


def wa_with_retirement(r: jax.Array, retired_frac: jax.Array, *,
                       iters: int = 80) -> jax.Array:
    """Equilibrium WA of a uniform workload on a drive that has retired a
    ``retired_frac`` fraction of its blocks: eq. 3 at the shrunken OP.
    This is the closed-form curve the forced-retirement test tracks
    (tests/test_faults.py) and the WA-vs-lifetime model overlay."""
    return wa_from_op_ratio(degraded_op_ratio(r, retired_frac), iters=iters)


def survival_fraction(degraded_at, t) -> jax.Array:
    """Fleet survival curve: fraction of drives still in service at write
    index ``t`` (broadcasting over ``t``).

    degraded_at: [B] per-drive degradation write index, -1 while alive
    (``FleetResult.time_to_degraded()``). A drive counts as surviving at
    ``t`` iff it never degraded or degraded strictly after ``t``.
    """
    d = jnp.asarray(degraded_at)[:, None]  # [B, 1] against flattened t
    t = jnp.asarray(t)
    alive = (d < 0) | (d > jnp.ravel(t))
    return jnp.mean(alive.astype(jnp.float32), axis=0).reshape(t.shape)


def wa_vs_lifetime(app, mig, *, window: int = 2000,
                   stride: int = 1) -> np.ndarray:
    """[K] windowed WA over one drive's lifetime from its cumulative
    (app, mig) trace — NaN for windows that complete no application
    writes (the drive was already degraded/frozen: a halted op advances
    neither counter), so the curve visibly ENDS where the drive died
    instead of flat-lining at a fake 1.0.

    window counts WRITES (must be a multiple of the trace stride), same
    boundary convention as ``RunResult.wa_curve``.
    """
    assert window % stride == 0, (window, stride)
    w = window // stride
    app = np.asarray(app)
    mig = np.asarray(mig)
    idx = np.arange(w, len(app) + 1, w) - 1
    prev = np.maximum(idx - w, -1)
    d_app = app[idx] - np.where(prev >= 0, app[prev], 0)
    d_mig = mig[idx] - np.where(prev >= 0, mig[prev], 0)
    return np.where(
        d_app > 0, (d_app + d_mig) / np.maximum(d_app, 1), np.nan
    )


# ---------------------------------------------------------------------------
# Appendix A: Lambert-W form (eq. 9), kept for fidelity + cross-validation.
# ---------------------------------------------------------------------------

def lambertw0(a: jax.Array, *, iters: int = 32) -> jax.Array:
    """Principal branch W0 of the Lambert W function, via Halley iteration.

    Valid for a >= -1/e. For the paper's use a ∈ (-1/e, 0), where W0 ∈ (-1, 0).
    Fixed iteration count keeps it jit-friendly; 32 Halley steps converge to
    float64 precision everywhere we evaluate it.
    """
    a = jnp.asarray(a)
    dtype = jnp.result_type(a, jnp.float32)
    a = a.astype(dtype)
    e = jnp.exp(jnp.asarray(1.0, dtype))
    # Initial guess: series near the branch point -1/e, else log-based guess.
    p = jnp.sqrt(jnp.maximum(2.0 * (e * a + 1.0), 0.0))
    w_branch = -1.0 + p - p * p / 3.0  # expansion around a = -1/e
    w_log = jnp.where(a > 0, jnp.log1p(a), a)  # fine for small |a|
    w = jnp.where(a < -0.2, w_branch, w_log)

    def body(_, w):
        ew = jnp.exp(w)
        f = w * ew - a
        # Halley: w' = w - f / (ew*(w+1) - (w+2)*f/(2w+2)). The denominator
        # vanishes at the branch point w = -1 (f = 0 there too): guard the
        # 0/0 by skipping the update when already converged.
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        step = jnp.where(jnp.abs(denom) > 1e-30, f / denom, 0.0)
        return jnp.where(jnp.abs(f) > 0.0, w - step, w)

    return jax.lax.fori_loop(0, iters, body, w)


def delta_from_op_ratio_lambertw(r: jax.Array) -> jax.Array:
    """Eq. (9): δ = -r · W0(-(1/r)·e^(-1/r)).

    The W-1 branch would return the trivial root δ = 1; W0 gives the
    equilibrium root in (0,1). Equivalent to ``delta_from_op_ratio`` (tested).
    """
    r = jnp.asarray(r)
    z = 1.0 / r  # PBA/LBA > 1
    return -r * lambertw0(-z * jnp.exp(-z))
