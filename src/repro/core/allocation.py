"""Over-provisioning allocation across temperature groups (paper §5.5).

The SSD (or any log-structured block pool) is partitioned into n groups with
logical sizes s_1..s_n (pages) and update frequencies p_1..p_n (probability an
incoming application write targets the group; Σp = 1). The task: split the
total over-provisioned space OP = PBA - LBA among groups to minimize

    WA = Σ_x p_x · WA(s_x, OP_x)                                   (eq. 5)

where each group behaves as a closed uniform-workload sub-SSD, so its δ_x
solves  s_x/(s_x+OP_x) = (δ_x-1)/ln(δ_x)  (eq. 4 ≡ eq. 3 per group).

Policies implemented:
  * ``allocate_by_size``       eq. (6):  OP_x = s_x · V,  V = OP/LBA
                               (what greedy-across-groups GC converges to)
  * ``allocate_by_frequency``  eq. (7):  OP_x = p_x · OP
  * ``allocate_closed_form``   eq. (8):  the average of the two — the paper's
                               near-optimal closed form, plus the §5.5.3
                               cold-group escape hatch.
  * ``optimal_allocation``     convex optimization on the simplex (the paper's
                               hill-climbing oracle baseline [20, 9]).
  * ``hillclimb_allocation``   the literal block-granularity hill climber.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .analytics import op_ratio_from_delta, wa_from_delta

__all__ = [
    "group_delta",
    "group_wa",
    "total_wa",
    "allocate_by_size",
    "allocate_by_frequency",
    "allocate_closed_form",
    "optimal_allocation",
    "hillclimb_allocation",
]


# ---------------------------------------------------------------------------
# Differentiable per-group WA.
#
# δ(r) inverts eq. 3 by bisection, which is not usefully differentiable, so we
# attach the implicit-function derivative:  with f(δ) = (δ-1)/ln(δ),
#   f'(δ) = (ln(δ) - (δ-1)/δ) / ln(δ)²   and  dδ/dr = 1 / f'(δ).
# ---------------------------------------------------------------------------

@jax.custom_jvp
def _delta_from_ratio(r: jax.Array) -> jax.Array:
    r = jnp.asarray(r)
    lo = jnp.full(jnp.shape(r), 1e-9, r.dtype)
    hi = jnp.full(jnp.shape(r), 1.0 - 1e-9, r.dtype)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        too_low = op_ratio_from_delta(mid) < r
        return jnp.where(too_low, mid, lo), jnp.where(too_low, hi, mid)

    # unroll: the body is a handful of [G]-sized ops, so on XLA:CPU the
    # loop-iteration overhead dominates; 80 bisection steps are kept for
    # bit-stable convergence (float32 lo/hi only reach their fixed point
    # near iteration ~60 on adversarial ratios)
    lo, hi = jax.lax.fori_loop(0, 80, body, (lo, hi), unroll=8)
    return 0.5 * (lo + hi)


@_delta_from_ratio.defjvp
def _delta_from_ratio_jvp(primals, tangents):
    (r,) = primals
    (rdot,) = tangents
    delta = _delta_from_ratio(r)
    ln = jnp.log(delta)
    fprime = (ln - (delta - 1.0) / delta) / (ln * ln)
    return delta, rdot / fprime


def group_delta(s: jax.Array, op: jax.Array) -> jax.Array:
    """δ_x for a group of logical size ``s`` with over-provisioning ``op``."""
    s = jnp.asarray(s, jnp.float32)
    op = jnp.asarray(op, jnp.float32)
    r = s / jnp.maximum(s + op, 1e-30)
    return _delta_from_ratio(jnp.clip(r, 1e-6, 1.0 - 1e-7))


def group_wa(s: jax.Array, op: jax.Array) -> jax.Array:
    """WA(s_x, OP_x) = 1/(1-δ_x)."""
    return wa_from_delta(group_delta(s, op))


def total_wa(s: jax.Array, p: jax.Array, op: jax.Array) -> jax.Array:
    """Eq. (5): frequency-weighted overall write-amplification."""
    return jnp.sum(jnp.asarray(p) * group_wa(s, op))


# ---------------------------------------------------------------------------
# The three closed-form policies (paper §5.5.1–5.5.3)
# ---------------------------------------------------------------------------

def allocate_by_size(s: jax.Array, op_total: jax.Array) -> jax.Array:
    """Eq. (6): OP_x = s_x · V with V = OP/LBA. Equalizes δ across groups."""
    s = jnp.asarray(s, jnp.float32)
    return s * (op_total / jnp.sum(s))


def allocate_by_frequency(p: jax.Array, op_total: jax.Array) -> jax.Array:
    """Eq. (7): OP_x = p_x · OP."""
    p = jnp.asarray(p, jnp.float32)
    return p / jnp.sum(p) * op_total


def allocate_closed_form(
    s: jax.Array,
    p: jax.Array,
    op_total: jax.Array,
    *,
    cold_rule: bool = True,
    cold_hit_rate_frac: float = 0.05,
    cold_op_frac: float = 0.05,
) -> jax.Array:
    """Eq. (8): OP_x = (s_x·V + p_x·OP)/2, the paper's near-optimal closed form.

    §5.5.3 cold-group handling: when the coldest group's hit rate (p/s) is
    below ``cold_hit_rate_frac`` of the second-coldest group's, it receives a
    fixed allocation of ``cold_op_frac`` × (smallest group's logical size) and
    the closed form is applied to the remaining groups/OP.

    Preserves Σ OP_x = OP and OP_x ≥ 0 by construction. Fully vectorized and
    jittable (the cold rule is a lax.cond-free masked computation).
    """
    s = jnp.asarray(s, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    op_total = jnp.asarray(op_total, jnp.float32)
    n = s.shape[0]

    def closed_form(s, p, op):
        v = op / jnp.sum(s)
        pn = p / jnp.maximum(jnp.sum(p), 1e-30)
        return 0.5 * (s * v + pn * op)

    base = closed_form(s, p, op_total)
    if not cold_rule or n < 2:
        return base

    hit = p / jnp.maximum(s, 1e-30)
    order = jnp.argsort(hit)
    coldest = order[0]
    second = order[1]
    is_skewed = hit[coldest] < cold_hit_rate_frac * hit[second]
    # Guard (beyond-paper): eq. 5 weights each group's WA by its update
    # frequency, so a group carrying a non-trivial share of writes must not
    # be starved even if its per-page rate is low (a big, lukewarm group can
    # sit just under the 5% hit-rate threshold while taking ~7% of traffic —
    # found by the hypothesis suite). The paper's TPC-C cold cluster has
    # p ≈ 0; restrict the fixed-allocation escape hatch to that regime.
    is_skewed &= p[coldest] / jnp.maximum(jnp.sum(p), 1e-30) < 0.02

    cold_op = cold_op_frac * jnp.min(s)
    cold_op = jnp.minimum(cold_op, op_total)  # never exceed the budget
    mask = jnp.arange(n) != coldest
    rest = closed_form(
        jnp.where(mask, s, 0.0), jnp.where(mask, p, 0.0), op_total - cold_op
    )
    with_cold = jnp.where(mask, rest, cold_op)
    return jnp.where(is_skewed, with_cold, base)


# ---------------------------------------------------------------------------
# Oracle optima (the paper's comparison baselines)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("steps",))
def optimal_allocation(
    s: jax.Array,
    p: jax.Array,
    op_total: jax.Array,
    *,
    steps: int = 600,
    lr: float = 0.25,
) -> jax.Array:
    """Minimize eq. (5) over the simplex {OP_x ≥ 0, Σ OP_x = OP}.

    The optimization space is convex (paper §5.5.3), so mirror descent
    (exponentiated gradient) on simplex weights converges to the optimum.
    Initialized at the closed form, which is already near-optimal.
    """
    s = jnp.asarray(s, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    op_total = jnp.asarray(op_total, jnp.float32)

    init = allocate_closed_form(s, p, op_total, cold_rule=False)
    theta0 = jnp.log(jnp.maximum(init / op_total, 1e-6))

    def objective(theta):
        u = jax.nn.softmax(theta)
        return total_wa(s, p, u * op_total)

    grad_fn = jax.value_and_grad(objective)

    def body(i, carry):
        theta, best_theta, best_wa = carry
        wa, g = grad_fn(theta)
        better = wa < best_wa
        best_theta = jnp.where(better, theta, best_theta)
        best_wa = jnp.where(better, wa, best_wa)
        g = jnp.where(jnp.isfinite(g), g, 0.0)
        gnorm = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30)
        step = lr / (1.0 + 0.02 * i)  # decaying step for last-mile precision
        return theta - step * g / gnorm, best_theta, best_wa

    init_carry = (theta0, theta0, objective(theta0))
    _, best_theta, _ = jax.lax.fori_loop(0, steps, body, init_carry)
    return jax.nn.softmax(best_theta) * op_total


def hillclimb_allocation(
    s: jax.Array,
    p: jax.Array,
    op_total: float,
    *,
    block_pages: int = 128,
    max_moves: int = 10_000,
) -> jax.Array:
    """The literal hill climber from [20]: start from a proportional split and
    repeatedly move one block of OP from the group whose WA suffers least to
    the group whose WA gains most, until no move improves. Convexity makes
    this globally optimal (to block granularity). Jittable via while_loop.
    """
    s = jnp.asarray(s, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    n = s.shape[0]
    step = jnp.asarray(float(block_pages), jnp.float32)
    op = allocate_by_size(s, op_total)

    def wa_of(op):
        return total_wa(s, p, op)

    def cond(carry):
        op, improved, it = carry
        return jnp.logical_and(improved, it < max_moves)

    def body(carry):
        op, _, it = carry
        base = wa_of(op)
        eye = jnp.eye(n, dtype=op.dtype) * step
        # WA after donating one block FROM group i (only if it has ≥ one block)
        can_give = op >= step
        wa_minus = jax.vmap(lambda d: wa_of(op - d))(eye)
        wa_minus = jnp.where(can_give, wa_minus, jnp.inf)
        giver = jnp.argmin(wa_minus)
        # WA after then granting that block TO group j
        op_after_take = op - eye[giver]
        wa_plus = jax.vmap(lambda d: wa_of(op_after_take + d))(eye)
        wa_plus = wa_plus.at[giver].set(jnp.inf)
        taker = jnp.argmin(wa_plus)
        new_op = op_after_take + eye[taker]
        improved = wa_plus[taker] < base - 1e-9
        return (jnp.where(improved, new_op, op), improved, it + 1)

    op, _, _ = jax.lax.while_loop(cond, body, (op, jnp.asarray(True), 0))
    return op
