"""Workload generators for the simulator (paper §6 experiments + op streams).

A workload phase = (group sizes in pages, per-group update probabilities,
optional per-group TRIM probabilities). Events are sampled i.i.d.: group ~
Categorical(p), page ~ Uniform(group), and — when the phase carries trim
probabilities — op ~ Bernoulli(trim_probs[group]) over {WRITE, TRIM}.
Frequency swaps are expressed as a sequence of phases; the simulator is run
segment-by-segment (oracle arrays differ per phase).

TRIM streams model deletes (Frankie et al., arXiv:1208.1794/1210.5975):
a trimmed page is unmapped until its next write, so a per-event trim
probability t holds an expected fraction t of the group's pages trimmed at
steady state — trimmed space acts as dynamic over-provisioning
(core/analytics.effective_op_ratio).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# op codes of an op-stream event (op, lba); the simulator dispatches on
# these at scan time (core/simulator, SimContext.with_trim)
OP_WRITE, OP_TRIM = 0, 1


@dataclasses.dataclass(frozen=True)
class Phase:
    sizes: tuple[int, ...]  # pages per group (sums to LBA)
    probs: tuple[float, ...]  # update probability per group (sums to 1)
    n_writes: int  # events in this phase (writes + trims for op phases)
    # probability that an event hitting group g is a TRIM instead of a
    # WRITE; () = pure-write phase (the default everywhere pre-TRIM)
    trim_probs: tuple[float, ...] = ()

    @property
    def has_trim(self) -> bool:
        return any(t > 0.0 for t in self.trim_probs)

    def page_group(self) -> np.ndarray:
        return np.repeat(
            np.arange(len(self.sizes), dtype=np.int32), self.sizes
        )

    def page_rate(self) -> np.ndarray:
        """True per-page update rate (oracle detector input)."""
        rates = np.asarray(self.probs) / np.maximum(np.asarray(self.sizes), 1)
        return np.repeat(rates.astype(np.float32), self.sizes)

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        """Draw the phase's [n_writes] page stream (pure-write phases)."""
        assert not self.has_trim, "op phase: use sample_ops()"
        _, lbas = self._sample_events(rng)
        return lbas

    def sample_ops(self, rng: np.random.Generator):
        """Draw the phase's op stream: (ops [n], lbas [n]) int32 arrays.

        For a pure-write phase this consumes exactly the draws
        :meth:`sample` would (same lbas, ops all WRITE), so routing a
        write-only workload through the op engine replays the identical
        stream — the bit-compatibility anchor of tests/test_write_engine.
        """
        groups, lbas = self._sample_events(rng)
        if not self.has_trim:
            return np.zeros(self.n_writes, np.int32), lbas
        tp = np.zeros(len(self.sizes))
        tp[: len(self.trim_probs)] = self.trim_probs
        ops = (rng.random(self.n_writes) < tp[groups]).astype(np.int32)
        return ops, lbas

    def _sample_events(self, rng: np.random.Generator):
        groups = rng.choice(
            len(self.probs), size=self.n_writes, p=np.asarray(self.probs)
        )
        offsets = np.concatenate([[0], np.cumsum(self.sizes)])[:-1]
        within = (rng.random(self.n_writes) * np.asarray(self.sizes)[groups]).astype(
            np.int64
        )
        return groups, (offsets[groups] + within).astype(np.int32)


# ---------------------------------------------------------------------------
# JAX-native sampling (on-device, inside the jitted fleet region)
# ---------------------------------------------------------------------------

def phase_param_arrays(phases, *, g_max: int | None = None, p_max: int | None = None):
    """Pad a phase sequence to fixed-shape arrays for on-device sampling.

    Returns a dict of numpy arrays: probs/sizes/offsets [P, G] (zero-padded),
    counts [P] (writes per phase; padded phases get 0 and are never reached),
    n_groups [P]. Drives of a fleet pad to shared (p_max, g_max) so their
    parameter pytrees stack.
    """
    p_n = p_max or len(phases)
    g_n = g_max or max(len(ph.sizes) for ph in phases)
    assert len(phases) <= p_n
    probs = np.zeros((p_n, g_n), np.float32)
    sizes = np.zeros((p_n, g_n), np.int32)
    offsets = np.zeros((p_n, g_n), np.int32)
    trim_probs = np.zeros((p_n, g_n), np.float32)
    counts = np.zeros(p_n, np.int32)
    n_groups = np.ones(p_n, np.int32)
    for i, ph in enumerate(phases):
        k = len(ph.sizes)
        probs[i, :k] = ph.probs
        sizes[i, :k] = ph.sizes
        offsets[i, :k] = np.concatenate([[0], np.cumsum(ph.sizes)])[:-1]
        trim_probs[i, : len(ph.trim_probs)] = ph.trim_probs
        counts[i] = ph.n_writes
        n_groups[i] = k
    return {
        "probs": probs, "sizes": sizes, "offsets": offsets,
        "trim_probs": trim_probs, "counts": counts, "n_groups": n_groups,
    }


def sample_phases_device(key, params: dict, n_total: int,
                         with_ops: bool = False):
    """Draw the [n_total] event stream of a phase sequence on device.

    Mirrors :meth:`Phase.sample` (group ~ Categorical(p), page ~ Uniform
    within group) with jax.random instead of a NumPy Generator — same
    distribution, different stream. Jit-safe: ``n_total`` is static, phase
    boundaries come from ``params["counts"]``.

    with_ops (static): also draw op ~ Bernoulli(trim_probs[phase, group])
    from a third key and return (ops, lbas) instead of lbas. The default
    False path is draw-for-draw the pre-op-stream sampler, so pure-write
    fleets keep their exact historical streams (bench cells stay
    bit-comparable); op-mode streams split the key three ways and are a
    DIFFERENT stream even at trim_probs == 0, like numpy-vs-jax sampling.
    """
    import jax
    import jax.numpy as jnp

    counts = jnp.asarray(params["counts"], jnp.int32)
    probs = jnp.asarray(params["probs"], jnp.float32)
    sizes = jnp.asarray(params["sizes"], jnp.int32)
    offsets = jnp.asarray(params["offsets"], jnp.int32)
    n_groups = jnp.asarray(params["n_groups"], jnp.int32)

    t = jnp.arange(n_total, dtype=jnp.int32)
    ph = jnp.searchsorted(jnp.cumsum(counts), t, side="right")
    ph = jnp.minimum(ph, counts.shape[0] - 1)
    if with_ops:
        k_grp, k_page, k_op = jax.random.split(key, 3)
    else:
        k_grp, k_page = jax.random.split(key)
    u_grp = jax.random.uniform(k_grp, (n_total,))
    u_page = jax.random.uniform(k_page, (n_total,))
    cdf = jnp.cumsum(probs, axis=1)  # [P, G]
    g = jnp.sum(u_grp[:, None] >= cdf[ph], axis=1).astype(jnp.int32)
    g = jnp.minimum(g, n_groups[ph] - 1)  # float-roundoff tail guard
    size = sizes[ph, g]
    within = jnp.minimum(
        (u_page * size.astype(jnp.float32)).astype(jnp.int32), size - 1
    )
    lbas = (offsets[ph, g] + within).astype(jnp.int32)
    if not with_ops:
        return lbas
    trim_probs = jnp.asarray(params["trim_probs"], jnp.float32)
    u_op = jax.random.uniform(k_op, (n_total,))
    ops = (u_op < trim_probs[ph, g]).astype(jnp.int32)
    return ops, lbas


def split_sizes(lba: int, fracs) -> tuple[int, ...]:
    fracs = np.asarray(fracs, np.float64)
    fracs = fracs / fracs.sum()
    sizes = np.floor(fracs * lba).astype(int)
    sizes[-1] += lba - sizes.sum()
    return tuple(int(s) for s in sizes)


def uniform(lba: int, n_writes: int) -> Phase:
    """§4: uniform random over the whole LBA (single group)."""
    return Phase((lba,), (1.0,), n_writes)


def two_modal(lba: int, n_writes: int, *, p_hot=0.9, frac_hot=0.5) -> Phase:
    sizes = split_sizes(lba, [1 - frac_hot, frac_hot])
    return Phase(sizes, (1 - p_hot, p_hot), n_writes)


def swap_phases(
    lba: int, writes_per_phase: int, *, p=(0.1, 0.9), fracs=(0.5, 0.5)
) -> tuple[Phase, Phase]:
    """§6.1 frequency swap: two equal groups whose probabilities swap."""
    sizes = split_sizes(lba, fracs)
    return (
        Phase(sizes, tuple(p), writes_per_phase),
        Phase(sizes, tuple(reversed(p)), writes_per_phase),
    )


def exponential_groups(lba: int, n_writes: int, n_groups: int = 5) -> Phase:
    """§6.1 generalization: exponentially increasing update frequencies
    (~3.2%, 6.4%, …, 51.2% for 5 groups), equal sizes."""
    raw = np.array([2.0 ** i for i in range(n_groups)])
    probs = tuple(raw / raw.sum())
    sizes = split_sizes(lba, [1.0] * n_groups)
    return Phase(sizes, probs, n_writes)


def pairwise_swap(phase: Phase, i: int, j: int, n_writes: int) -> Phase:
    """Swap the update frequencies of groups i and j (Fig. 8 matrix)."""
    probs = list(phase.probs)
    probs[i], probs[j] = probs[j], probs[i]
    return Phase(phase.sizes, tuple(probs), n_writes)


def tpcc_like(lba: int, n_writes: int) -> Phase:
    """TPC-C_init-shaped synthetic (paper Fig. 9): two temperature clusters,
    the hot one ~8× hotter per page and similar aggregate size, plus a very
    cold majority (54% of pages never/rarely updated)."""
    sizes = split_sizes(lba, [0.54, 0.26, 0.20])
    # per-page rate ratio cold:warm:hot ≈ 0.02 : 1 : 8 → aggregate probs
    agg = np.array([0.54 * 0.02, 0.26 * 1.0, 0.20 * 8.0])
    probs = tuple(agg / agg.sum())
    return Phase(sizes, probs, n_writes)


# ---------------------------------------------------------------------------
# op-stream (TRIM) workloads
# ---------------------------------------------------------------------------

def trimmed(phase: Phase, trim_frac) -> Phase:
    """Interleave TRIMs into any phase: each event that hits group g is a
    TRIM with probability ``trim_frac`` (scalar) or ``trim_frac[g]``.

    With uniform page selection inside the group, a per-event trim
    probability t holds an expected fraction t of the group's pages
    trimmed at steady state (a page's mapped bit is a two-state chain
    flipped by its own WRITE/TRIM events) — the knob the utilization
    sweep turns.
    """
    if np.ndim(trim_frac) == 0:
        tp = (float(trim_frac),) * len(phase.sizes)
    else:
        assert len(trim_frac) == len(phase.sizes)
        tp = tuple(float(t) for t in trim_frac)
    assert all(0.0 <= t <= 1.0 for t in tp), tp
    return dataclasses.replace(phase, trim_probs=tp)


def utilization_sweep(lba: int, n_ops: int, trim_fracs=(0.0, 0.1, 0.25, 0.5)):
    """Single-group uniform phases holding trim fraction t of the LBA
    trimmed at steady state, one per entry of ``trim_fracs`` — the
    Frankie-style effective-OP sweep (each phase is an independent drive
    of a fleet, not a segment sequence)."""
    return [trimmed(uniform(lba, n_ops), t) for t in trim_fracs]


def tpcc_churn(lba: int, n_ops: int) -> Phase:
    """TPC-C table-churn op stream: the tpcc_like temperature shape with
    the insert/update/delete lifecycle layered on.

    Group 0 (history/item, the cold majority) is append-mostly — writes
    only. Group 1 (stock/customer) updates in place with light pruning.
    Group 2 (orders/new-order) is the churn cluster: rows are inserted,
    updated while open, and deleted on delivery — a third of its events
    are TRIMs, so ~33% of the hot table floats unmapped at steady state
    and its share of the pool becomes dynamic over-provisioning.
    """
    return trimmed(tpcc_like(lba, n_ops), (0.0, 0.05, 1.0 / 3.0))
