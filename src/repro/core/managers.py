"""Block-manager presets (paper §6 comparison points) + run helpers."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.simulator import SimContext, run
from repro.core.ssd import Geometry, ManagerConfig, init_state
from repro.core.workloads import Phase


def wolf(**kw) -> ManagerConfig:
    """The paper's system: measured stats, closed-form OP allocation,
    movement operations, greedy GC."""
    return ManagerConfig(
        name="wolf", alloc_mode="wolf", gc_policy="greedy",
        movement_ops=True, td_mode="static", **kw
    )


def wolf_dynamic(**kw) -> ManagerConfig:
    """Wolf with dynamic group creation/merging + bloom detector (TPC-C)."""
    return ManagerConfig(
        name="wolf-dynamic", alloc_mode="wolf", gc_policy="greedy",
        movement_ops=True, td_mode="bloom", dynamic_groups=True,
        max_groups=12, **kw
    )


def fdp(**kw) -> ManagerConfig:
    """Stoica et al. [20] as characterized in the paper: fixed group order
    with ASSUMED frequencies (hit rate doubles per group), LRU GC, no
    movement operations; pages move between groups instead."""
    return ManagerConfig(
        name="fdp", alloc_mode="fdp_assumed", gc_policy="lru",
        movement_ops=False, td_mode="fdp", **kw
    )


def single_group(**kw) -> ManagerConfig:
    """Grey-line baseline: all pages mixed in one group."""
    return ManagerConfig(
        name="single", alloc_mode="single", gc_policy="greedy",
        movement_ops=False, td_mode="static", max_groups=kw.pop("max_groups", 1),
        **kw
    )


def wolf_lru(**kw) -> ManagerConfig:
    """Ablation for Fig. 2 (greedy vs LRU under movement operations)."""
    return ManagerConfig(
        name="wolf-lru", alloc_mode="wolf", gc_policy="lru",
        movement_ops=True, td_mode="static", **kw
    )


def wolf_wear(**kw) -> ManagerConfig:
    """Wolf with wear-leveling victim scoring: the (α, β, γ, τ) score at
    the ``wear`` preset point (α=1, β>0) trades reclaim efficiency against
    per-block P-E imbalance — the ROADMAP's "does wear-leveling cost Wolf
    its WA advantage?" comparison point. β is swept per-drive in fleets
    (``gc_beta=...``); the preset default is GC_WEIGHT_PRESETS["wear"]."""
    return ManagerConfig(
        name="wolf-wear", alloc_mode="wolf", gc_policy="wear",
        movement_ops=True, td_mode="static", **kw
    )


def wolf_trim_aware(**kw) -> ManagerConfig:
    """Wolf with the τ term active: victims rich in trimmed-but-unerased
    slots are deprioritised (the ROADMAP's trim-aware GC open idea)."""
    return ManagerConfig(
        name="wolf-trim-aware", alloc_mode="wolf", gc_policy="trim_aware",
        movement_ops=True, td_mode="static", **kw
    )


def wolf_endurance(**kw) -> ManagerConfig:
    """Wolf on an AGING drive: blocks die deterministically once their P-E
    count crosses ``endurance_pe_limit`` (fault_rate_worn defaults to 1.0),
    retire into the spare pool, and shrink the OP the §5.5 allocator
    divides — the WA-vs-lifetime comparison point (tests/test_faults.py,
    bench_fleet's endurance row). Pass ``fault_rate=...`` for an
    additional age-independent failure floor."""
    return ManagerConfig(
        name="wolf-endurance", alloc_mode="wolf", gc_policy="greedy",
        movement_ops=True, td_mode="static",
        endurance_pe_limit=kw.pop("endurance_pe_limit", 40), **kw
    )


# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunResult:
    app: np.ndarray  # cumulative application writes
    mig: np.ndarray  # cumulative migrations
    state: dict
    # trace stride: element j covers writes up to step (j+1)·stride - 1
    # (1 = dense per-write trace; see simulator.scan_writes)
    stride: int = 1

    @property
    def wa_total(self) -> float:
        return float((self.app[-1] + self.mig[-1]) / max(self.app[-1], 1))

    def wa_curve(self, window: int = 2000) -> np.ndarray:
        """Windowed WA over time: (Δapp+Δmig)/Δapp per window.

        ``window`` counts WRITES, not trace elements, so curves from runs
        with different trace strides are comparable — window k covers
        writes (k·window, (k+1)·window], boundaries the strided trace
        samples exactly (window must be a multiple of the stride; a
        stride-E trace at element j equals the dense trace at step
        (j+1)·E - 1, so curves agree elementwise across strides). For an
        op stream the scan steps are EVENTS (writes + trims), so a window
        covers ``window`` events and Δapp counts just its writes — the
        WA ratio stays exact, only the window boundary unit changes.
        """
        assert window % self.stride == 0, (window, self.stride)
        w = window // self.stride
        app, mig = self.app, self.mig
        # boundaries AFTER k·window writes: trace elements k·w - 1; the
        # first window's left boundary is the (virtual) zero sample before
        # any write, so the burn-in window is included
        idx = np.arange(w, len(app) + 1, w) - 1
        prev = np.maximum(idx - w, -1)
        d_app = app[idx] - np.where(prev >= 0, app[prev], 0)
        d_mig = mig[idx] - np.where(prev >= 0, mig[prev], 0)
        return np.where(d_app > 0, (d_app + d_mig) / np.maximum(d_app, 1), 1.0)


def fdp_assumed_arrays(phase: Phase, g_max: int):
    """FDP's FIXED assumptions, taken from the initial phase: group i+1 is
    2× hotter per page (paper §6.2 green line); sizes from the phase."""
    n = min(len(phase.sizes), g_max)
    sizes = np.asarray(phase.sizes[:n], np.float64)
    rate = 2.0 ** np.arange(n)  # assumed per-page rates, relative
    agg = sizes * rate
    assumed_p = np.zeros(g_max, np.float32)
    assumed_p[:n] = agg / agg.sum()
    fdp_rate = np.zeros(g_max, np.float32)
    fdp_rate[:n] = (assumed_p[:n] / sizes).astype(np.float32)
    return assumed_p, fdp_rate


def build_drive(
    geom: Geometry,
    mcfg: ManagerConfig,
    phases: list[Phase],
    *,
    init_p_from_phase: bool = True,
    g_max: int | None = None,
    use_bloom: bool | None = None,
):
    """Pre-conditioned drive state + oracle arrays for a phase sequence.

    Shared by :func:`simulate` (one drive) and ``core/fleet.py`` (stacked
    drives). ``g_max`` pads the per-group arrays beyond ``mcfg.max_groups``
    so drives with different group caps can share one vmapped state shape;
    ``use_bloom`` forces bloom-filter sizing (fleets mixing bloom and
    non-bloom drives must share it fleet-wide).

    Returns (st, n_groups, assumed_p [g_max], fdp_rate [g_max],
    page_rates [P, LBA] — the true per-page update rate of every phase —
    and page_group0 [LBA], the layout group of every logical page: the
    residence group a write that re-maps a TRIMMED page lands in).
    """
    import jax.numpy as jnp

    # the drive's OWN cap decides whether pages are separated at all;
    # g_max only pads the per-group arrays for fleet stacking
    first = phases[0]
    n_groups = 1 if mcfg.max_groups == 1 else len(first.sizes)
    if g_max is not None and g_max != mcfg.max_groups:
        mcfg = dataclasses.replace(mcfg, max_groups=g_max)
    g_max = mcfg.max_groups
    page_group = (
        np.zeros(geom.lba_pages, np.int32)
        if n_groups == 1
        else first.page_group()
    )
    if use_bloom is None:
        use_bloom = mcfg.td_mode == "bloom"
    st = init_state(geom, mcfg, page_group, n_groups, use_bloom=use_bloom)
    if init_p_from_phase and n_groups > 1:
        p0 = np.zeros(g_max, np.float32)
        p0[: len(first.probs)] = first.probs
        st = st.replace(grp_p=jnp.asarray(p0))
    assumed_p, fdp_rate = fdp_assumed_arrays(first, g_max)
    uniform_rate = np.full(geom.lba_pages, 1.0 / geom.lba_pages, np.float32)
    page_rates = np.stack([
        phase.page_rate() if n_groups > 1 else uniform_rate
        for phase in phases
    ])
    return st, n_groups, assumed_p, fdp_rate, page_rates, page_group


def simulate(
    geom: Geometry,
    mcfg: ManagerConfig,
    phases: list[Phase],
    *,
    seed: int = 0,
    init_p_from_phase: bool = True,
    gc_impl: str = "bulk",
    fast_path: bool = True,
    trace_every: int = 1,
    unroll: int = 1,
    ops_stream: bool | None = None,
    faults: bool | None = None,
) -> RunResult:
    """Run a (possibly multi-phase) workload under a manager preset.

    gc_impl: "bulk" (vectorized drain, default) or "reference" (the
    per-page oracle) — tests/test_bulk_gc.py asserts they agree.
    fast_path: False selects the seed-shaped single-path step
    (tests/test_write_engine.py asserts it agrees with the split engine).
    trace_every / unroll: trace stride and scan unroll factor
    (simulator.scan_writes); trace_every must divide every phase length.
    ops_stream: None (default) routes through the op-stream engine iff any
    phase carries TRIMs; True forces it for pure-write phases too — the
    sampled events are then identical (Phase.sample_ops consumes the same
    draws), which tests/test_write_engine.py uses to pin the op engine
    bit-identical to the write engine on all-WRITE streams.
    faults: None (default) traces the fault layer iff ``mcfg.has_faults``;
    True forces it on for a zero-rate config — the fault trace with an
    empty event set, which tests/test_faults.py pins bit-identical to the
    fault-free engine.
    """
    rng = np.random.default_rng(seed)
    st, n_groups, assumed_p, fdp_rate, page_rates, page_group0 = build_drive(
        geom, mcfg, phases, init_p_from_phase=init_p_from_phase
    )
    if ops_stream is None:
        ops_stream = any(ph.has_trim for ph in phases)
    assert ops_stream or not any(ph.has_trim for ph in phases), (
        "phases carry TRIMs: ops_stream=False is not available"
    )
    if faults is None:
        faults = mcfg.has_faults
    assert faults or not mcfg.has_faults, (
        "mcfg can fail erases: faults=False is not available"
    )
    ctx = SimContext(
        geom, mcfg, n_groups, use_bloom=mcfg.td_mode == "bloom",
        gc_impl=gc_impl, fast_path=fast_path,
        use_movement=mcfg.movement_ops,
        can_demote=mcfg.td_mode != "static",
        use_dynamic=mcfg.dynamic_groups,
        use_closed_alloc=mcfg.alloc_mode in ("wolf", "optimal", "fdp_assumed"),
        trace_every=trace_every, unroll=unroll,
        with_trim=ops_stream, with_faults=faults,
    )
    apps, migs = [], []
    for phase, page_rate in zip(phases, page_rates):
        kw = {}
        if ops_stream:
            ops, lbas = phase.sample_ops(rng)
            kw = dict(ops=ops, page_group0=page_group0)
        else:
            lbas = phase.sample(rng)
        st, trace = run(
            ctx, st, lbas,
            page_rate=page_rate, assumed_p=assumed_p, fdp_rate=fdp_rate,
            **kw,
        )
        apps.append(np.asarray(trace["app"]))
        migs.append(np.asarray(trace["mig"]))
    return RunResult(
        np.concatenate(apps), np.concatenate(migs), st, stride=trace_every
    )