"""Event-granularity SSD simulator (jittable, lax.scan over an op stream).

One scan step = one application event. The engine is an OP-STREAM engine:
an event is ``(op, lba)`` with ``op ∈ {OP_WRITE, OP_TRIM}`` (pure-write
contexts scan bare ``lba`` streams — see the bit-compatibility note
below). A WRITE:
  1. invalidate the page's old physical slot (one gather in the packed
     ``page_map``),
  2. pick the target group (temperature detection, §5.6 / oracle; a page
     re-mapped after a TRIM inherits its workload layout group via
     ``page_group0``),
  3. garbage-collect inside the group if it's out of budgeted space (§5.4),
  4. append the page to the group's active block,
  5. every h writes: interval bookkeeping (§5.1) — EWMA update frequencies,
     re-allocate over-provisioning (§5.5), create/merge groups (§5.2),
  6. movement operations (§5.3): ≤1 proactive compaction GC per step on the
     most block-surplus group, donating redeemed blocks to the pool.
A TRIM unmaps the page and kills its physical slot (:func:`_trim_page`,
one fused ``kernels/write_path.apply_trim`` op + O(1) carried-counter
updates). It frees space, so it can never trip the GC / valve / movement
predicates, and it completes no application write, so it never closes a
§5.1 interval — TRIM has only a fast path.

Architecture (op-stream layer):

* **TRIM is dynamic over-provisioning** (Frankie et al., arXiv:1208.1794).
  ``SimState`` carries ``mapped_pages`` (scalar == mapped LBAs) and
  ``grp_live`` ([G] == mapped pages per group), maintained at every
  map/unmap/GC site and cross-checked by ``SimState.check_invariants``.
  The §5.5 allocator and the detector hit rates consume these EFFECTIVE
  group sizes, so trimmed space automatically re-enters the OP budgets at
  the next §5.1 interval and equilibrium WA tracks
  ``analytics.wa_from_op_ratio(effective_op_ratio(r, t))``
  (tests/test_trim.py).

* **Bit-compatibility with pure-write runs.** ``SimContext.with_trim``
  is static: ``False`` (default) traces the historical (lba, t) step —
  pure-write fleets keep their exact streams, step structure, and scalar
  §5.1 interval predicate, so results are bit-identical to the
  pre-op-stream engine at zero cost. ``True`` scans (op, lba, t) triples;
  an all-WRITE op stream still reproduces the pure-write run
  bit-identically (state, counters, WA curves — asserted under jit and
  vmap in tests/test_write_engine.py) because a WRITE event executes the
  identical write body; only the interval predicate reads the carried
  ``n_app`` instead of the scan clock (equal values on all-WRITE
  streams, where n_app == t+1 at the read). Under vmap the op dispatch
  is a select and the §5.1 predicate is per-drive, which is why
  ``core/fleet.py`` partitions trim-bearing drives into their own
  sub-batches.

Architecture (fault-injection / bad-block retirement layer):

* **Faults are data, not step structure.** ``SimContext.with_faults``
  (static, default ``False``) gates the traced fault machinery — the
  per-erase Bernoulli draw, the halt guard, the retired-capacity term in
  the §5.5 allocator — but the RATES live in ``policy``
  (``fault_rate``, ``fault_rate_worn``, ``endurance_limit``,
  ``fault_seed``), so a fleet sweeps failure rates × endurance limits in
  ONE compiled grid and faulty + fault-free drives share a sub-batch
  (``with_faults`` is deliberately NOT a ``fleet._part_key`` dimension).
  ``with_faults=False`` traces the exact pre-fault step: zero-fault
  drives stay bit-identical to the fault-free engine under jit and vmap
  (tests/test_faults.py).

* **Retry-then-retire at every erase site.** Each of the three GC drains
  is wrapped by :func:`_erase_fault_retire` inside :func:`_gc_one`'s
  dieted cond: one counter-based uniform (:func:`_fault_uniform`, a pure
  function of ``(fault_seed, fault_draws)`` — replayable) decides the
  whole retry ladder. The erase fails iff ``u < rate``; all
  ``1 + erase_max_retries`` attempts fail iff ``u < rate^(1+retries)``,
  and then the block RETIRES: the erase is undone from the wear
  aggregates (a failed erase completes no P-E cycle), the block enters
  the terminal ``RETIRED`` state keeping its group label
  (``grp_retired`` follows §5.2 merges), and a spare is drawn. The rate
  jumps from ``fault_rate`` to ``fault_rate_worn`` (default 1.0) once
  the block's P-E count crosses ``endurance_limit`` — deterministic
  block death at the limit, the simplest endurance model that makes WA
  vs LIFETIME a measurable curve.

* **Graceful degradation, not invariant violation.** Retired capacity is
  subtracted from the §5.5 OP budget at the next interval, so the
  allocator divides the SHRUNKEN physical space and
  ``predicted_wa()``/``model_error()`` track the degraded geometry. When
  the spare pool is dry the drive flips ``drive_status`` to
  STATUS_DEGRADED (recording ``degraded_at``) and :func:`_halt_wrap`
  freezes every later op into a counted no-op — at fleet scale a dead
  drive is an inert lane in its vmapped sub-batch, masked exactly like
  PR 6's filler drives, and never poisons its neighbors.

Architecture (post fast-path refactor — see also the bulk-GC notes below):

* **O(1) incremental accounting.** The paper treats pool occupancy and
  per-group budgets as counters, and so does the simulator: ``SimState``
  carries ``free_blocks`` (a scalar, == ``(state == FREE).sum()`` always)
  and ``grp_surplus`` (``grp_phys - grp_alloc`` masked to active groups),
  maintained at the handful of sites that change block state
  (:func:`_pop_free_block`, the two GC drains, :func:`_recompute_alloc`,
  group create/merge). Every per-write predicate — the GC low-pool check,
  the emergency valve, movement-op headroom — is an O(1) scalar read; full
  reductions over the block array survive only inside per-GC victim
  selection and ``SimState.check_invariants`` (the debug checker that
  proves the counters never drift).

* **Fast path / heavy path.** ``make_step`` splits the write into a lean
  fast path — invalidate counters, pick the target group, append to the
  group's open active block through the fused ``kernels/write_path`` op
  (Pallas on TPU, flat gather/scatter lowering elsewhere) — and a heavy
  path (GC, emergency valve, movement ops, §5.1 interval bookkeeping)
  entered only when the scalar predicates demand it: the active block is
  full, the pool is at reserve, a group holds redeemable surplus, or the
  interval elapses. GC is a rare event amortized over many steady-state
  writes (cf. Nagel et al., arXiv:1807.09313); under plain jit the heavy
  machinery is a real untaken branch on most writes. The seed-shaped
  single-path step survives as ``SimContext.fast_path=False`` and is the
  step-equivalence oracle (tests/test_write_engine.py).

* **Chunked scan + strided tracing.** :func:`scan_writes` scans chunks of
  ``trace_every`` writes (inner scan ``unroll``-ed) and emits the
  cumulative (n_app, n_mig) counters once per chunk instead of per write —
  the trace at stride E samples exactly the dense trace at steps E, 2E, …
  Write-order semantics are untouched: chunking only regroups scan
  iterations, every write still sees the state its predecessors left.
  Dense tracing (``trace_every=1``) is the default everywhere.

Architecture (post bulk-GC refactor):

* **State** is a :class:`repro.core.ssd.SimState` — a frozen dataclass
  registered as a JAX pytree. Mutating helpers return successors via
  ``st.replace(...)``; there are no ad-hoc ``dict(st)`` copies. The
  logical→physical map is ONE packed int32 array (``page_map = blk · B +
  slot``, ``-1`` unmapped): lookups, invalidates, and writes each cost a
  single gather/scatter instead of the former ``map_blk``/``map_slot`` pair.

* **GC drains in bulk.** :func:`_gc_drain_bulk` migrates a victim's live
  pages in one shot: the ``[B]`` ``slot_lba``/``valid`` lanes are read at
  once, per-slot target groups come from the demotion policy, pages are
  segment-counted per target group, fresh blocks are claimed up front (one
  per overflowing target group, in the exact order the sequential pop would
  produce), and the landings are chunked writes — dense one-hot masked ops
  for the group/block-sized updates (XLA:CPU expands vector-index ``.at[]``
  scatters into a while loop each, measured at ~4× the whole drain's cost)
  and flat 1-D scatters for the two capacity-sized ones. The slot-content
  copy itself routes through ``kernels/gc_compact`` (Pallas-backed on TPU,
  the flattened-index lowering elsewhere). Only the *demotion
  decision* keeps a sequential flavor: §5.6 demotion reads hit rates, which
  drift as the drain moves pages, so when any page is demotion-flagged a
  ``lax.scan`` carrying just the [G] group sizes replays the per-page
  decisions bit-exactly (sort-free; the common static-detector case
  short-circuits to constant targets). No ``fori_loop`` over victim slots
  remains; the former per-page path survives as
  :func:`_gc_drain_reference` (``SimContext.gc_impl="reference"``) and is
  asserted elementwise-identical in tests/test_bulk_gc.py.

* **GC victim selection is ONE traced score, not a policy branch.**
  :func:`_select_victim` maximises

      S(blk) = α·(B − live) − γ·stamp − β·erase_count − τ·trim_dead

  over the CLOSED blocks of the GC group (others masked to -inf).
  α scores reclaim benefit (pages freed by erasing the block), γ scores
  migration cost by recency (a recently-claimed block's pages are about
  to die on their own — migrating them is wasted work, the classic LRU
  rationale), β steers selection away from high-P-E blocks (wear
  leveling against the carried ``erase_count``), and τ deprioritises
  blocks rich in trimmed-but-unerased slots (``trim_dead``). The legacy
  policies are EXACT weight points — greedy = (1,0,0,0) ≡ argmin(live),
  lru = (0,0,1,0) ≡ argmin(stamp), bit-identical victims including the
  first-index tie-break, because every term is an int32 counter cast to
  float32 (exact below 2^24) — and wear/trim-aware policies are just
  other points of the same traced (α, β, γ, τ) vector, so a vmapped
  fleet sweeps the whole policy space in one compiled grid with no
  step-structure change. Victim selection stays the only full
  block-array reduction on the write path: the score reads four carried
  [K] counters elementwise, and every erase site maintains
  ``erase_count``/``erase_total``/``erase_sq_total``/``trim_dead`` in
  O(1) (cross-checked by ``SimState.check_invariants``).

* **Policy switches: traced data where drives differ, trace-time structure
  where they can't.** The GC weight vector, movement firing, FDP
  assumption arrays, and the §5.1 constants ``ewma_a``/``h`` live in a
  per-drive ``policy`` pytree of scalars selected with ``lax.cond`` —
  under jit they are runtime branches, under ``jax.vmap`` selects, which
  is what lets ``core/fleet.py`` batch drives with different manager
  configs (including EWMA/interval/GC-weight sweeps) into one jitted
  ``vmap(lax.scan)``. But switches that define step STRUCTURE — the
  temperature detector, movement ops, dynamic groups, closed-form
  allocation — dispatch at TRACE time from ``SimContext``
  (``can_demote``/``use_movement``/``use_dynamic``/``use_closed_alloc``):
  a vmapped ``lax.switch`` executes every branch and selects, so
  ``core/fleet.py`` partitions fleets into structure-homogeneous
  sub-batches (``fleet._part_key``) and each compiled step carries only
  the machinery its drives can ever run. Conditionals that remain are
  SELECT-DIETED (``_cond_fields``): their branches return only the fields
  they can modify, never the whole ~29-array state pytree. When every
  drive of a fleet shares ``h``, the interval predicate stays a scalar
  (``SimContext.per_drive_interval=False``) so the §5.1 bookkeeping
  remains a real every-h-steps branch, not a per-step select.

GC migrations re-enter the same write semantics (so migrated pages can be
demoted by the detector, as in Listing 1/3 of the paper).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.allocation import (
    allocate_by_frequency,
    allocate_by_size,
    allocate_closed_form,
)
from repro.core.ssd import (
    CLOSED,
    FREE,
    OPEN,
    RETIRED,
    STATUS_DEGRADED,
    STATUS_OK,
    Geometry,
    ManagerConfig,
    SimState,
    bloom_bits,
    surplus_of,
)
from repro.core.workloads import OP_TRIM
from repro.kernels.gc_compact.ops import compact_slots
from repro.kernels.write_path.ops import apply_trim, apply_write

INT_MAX = jnp.iinfo(jnp.int32).max

# policy codes (traced per-drive scalars; see policy_from_config)
ALLOC_CLOSED, ALLOC_FDP, ALLOC_SIZE, ALLOC_FREQ = 0, 1, 2, 3
_ALLOC_CODES = {
    "wolf": ALLOC_CLOSED,
    "optimal": ALLOC_CLOSED,
    "fdp_assumed": ALLOC_FDP,
    "size": ALLOC_SIZE,
    "freq": ALLOC_FREQ,
    "single": ALLOC_SIZE,
}
TD_STATIC, TD_FDP, TD_BLOOM = 0, 1, 2
_TD_CODES = {"static": TD_STATIC, "fdp": TD_FDP, "bloom": TD_BLOOM}


@dataclasses.dataclass(frozen=True)
class SimContext:
    """Static context threaded through the jitted step.

    Holds the SHAPE-defining geometry and the scalar paper constants shared
    by every drive of a fleet; everything that may differ per drive lives in
    the traced ``policy`` pytree.
    """

    geom: Geometry
    mcfg: ManagerConfig
    n_groups: int  # initial groups (may grow in dynamic mode)
    # static because it gates array SHAPES and traced branches: when False
    # the bloom detector branch is structurally absent (vmapped fleets then
    # never pay per-step selects over the [G, bits] filter pair) and the
    # state carries (G, 1) placeholders
    use_bloom: bool = True
    # GC drain implementation: "bulk" (vectorized, default) or "reference"
    # (the per-page fori_loop it replaced — kept as the equivalence oracle)
    gc_impl: str = "bulk"
    # static because it gates the interval predicate's batching: False keeps
    # ((t+1) % h == 0) a SCALAR under vmap (every drive shares h, the §5.1
    # work stays a real branch); True reads the per-drive policy["h"], which
    # under vmap turns the interval machinery into per-step selects — only
    # fleets actually sweeping the interval length pay that
    per_drive_interval: bool = False
    # step engine: True = fast-path/heavy-path split (default); False = the
    # seed-shaped single-path step, kept as the step-equivalence oracle
    fast_path: bool = True
    # op-stream mode: when True the scan consumes (op, lba) events and the
    # step dispatches WRITE/TRIM (both engines). Static because it gates
    # traced structure AND the interval clock: pure-write contexts keep the
    # scalar ((t+1) % h) predicate (t unbatched under vmap), op contexts
    # read the carried n_app (write counts diverge across drives once
    # trims interleave, so the §5.1 predicate is per-drive there). False
    # traces the EXACT pre-op-stream step — pure-write fleets pay nothing.
    with_trim: bool = False
    # static because they gate traced STRUCTURE (like use_bloom): when False
    # the movement-op / §5.6-demotion / §5.2-dynamic-group / closed-form-
    # allocation machinery is structurally absent from the compiled step,
    # so vmapped fleets whose sub-batch can't need it never pay its
    # per-step (or per-interval) cost. core/fleet.py partitions on these.
    use_movement: bool = True
    can_demote: bool = True
    use_dynamic: bool = True
    # the eq.-8 closed-form OP allocation embeds an 80-iteration bisection
    # (analytics eq. 3 inversion) per §5.1 interval; size/freq-allocated
    # drives never read its result
    use_closed_alloc: bool = True
    # fault-injection / bad-block retirement layer. Static because it gates
    # traced STRUCTURE (the per-erase fault draw, the degraded-drive halt
    # guard, the retired-capacity term of the §5.5 allocator) — but it is
    # deliberately NOT a fleet partition dimension: fault rates, endurance
    # limits, and seeds are per-drive POLICY data, so faulty and fault-free
    # drives share one compiled sub-batch (the fleet layer sets this per
    # sub-batch iff any drive's mcfg.has_faults). False traces the EXACT
    # fault-free step; True with zero-rate policy data produces
    # elementwise-identical values on every pre-existing field.
    with_faults: bool = False
    # trace stride: emit the cumulative (n_app, n_mig) counters after every
    # E-th write instead of every write (must divide the segment length);
    # the scan is then chunked [T//E, E] and the inner chunk emits nothing
    trace_every: int = 1
    # lax.scan unroll factor for the (inner) write loop — amortizes
    # XLA:CPU per-iteration dispatch; semantics-free
    unroll: int = 1

    @property
    def h(self) -> int:
        return max(16, int(self.geom.lba_pages * self.mcfg.interval_frac))

    @property
    def f_min_pages(self) -> int:
        return self.geom.n_luns * self.geom.pages_per_block


def policy_from_config(ctx: SimContext, assumed_p=None, fdp_rate=None) -> dict:
    """Lower a ManagerConfig's policy switches to a traced pytree.

    assumed_p/fdp_rate: [G] FDP fixed-assumption arrays (zeros if unused).
    """
    g_max = ctx.mcfg.max_groups
    if assumed_p is None:
        assumed_p = jnp.zeros(g_max, jnp.float32)
    if fdp_rate is None:
        fdp_rate = jnp.zeros(g_max, jnp.float32)
    assert ctx.use_bloom or ctx.mcfg.td_mode != "bloom", (
        "bloom detector requested but ctx.use_bloom is False"
    )
    assert ctx.use_movement or not ctx.mcfg.movement_ops, (
        "movement ops requested but ctx.use_movement is False"
    )
    assert ctx.can_demote or ctx.mcfg.td_mode == "static", (
        f"detector {ctx.mcfg.td_mode!r} can demote but ctx.can_demote is False"
    )
    assert ctx.use_dynamic or not ctx.mcfg.dynamic_groups, (
        "dynamic groups requested but ctx.use_dynamic is False"
    )
    assert ctx.use_closed_alloc or ctx.mcfg.alloc_mode not in (
        "wolf", "optimal", "fdp_assumed"
    ), f"alloc {ctx.mcfg.alloc_mode!r} needs the closed form"
    assert ctx.with_faults or not ctx.mcfg.has_faults, (
        "mcfg can fail erases but ctx.with_faults is False"
    )
    return {
        "alloc_mode": jnp.asarray(_ALLOC_CODES[ctx.mcfg.alloc_mode], jnp.int32),
        # (α, β, γ, τ) victim-score weights (ManagerConfig.gc_weights):
        # per-drive TRACED data, so one vmapped fleet sweeps the weight
        # space — greedy/LRU/wear/trim-aware are all points of this vector
        "gc_w": jnp.asarray(ctx.mcfg.gc_weights(), jnp.float32),
        "movement_ops": jnp.asarray(ctx.mcfg.movement_ops),
        "td_mode": jnp.asarray(_TD_CODES[ctx.mcfg.td_mode], jnp.int32),
        "dynamic_groups": jnp.asarray(ctx.mcfg.dynamic_groups),
        "max_groups": jnp.asarray(ctx.mcfg.max_groups, jnp.int32),
        "f_min_pages": jnp.asarray(ctx.f_min_pages, jnp.int32),
        # §5.1 constants as per-drive sweep axes (ROADMAP: online frequency
        # re-estimation); h doubles as the interval predicate when
        # ctx.per_drive_interval is True
        "h": jnp.asarray(ctx.h, jnp.int32),
        "ewma_a": jnp.asarray(ctx.mcfg.ewma_a, jnp.float32),
        "assumed_p": jnp.asarray(assumed_p, jnp.float32),
        "fdp_rate": jnp.asarray(fdp_rate, jnp.float32),
        # fault injection (per-drive TRACED data — a fleet sweeps failure
        # rates × endurance limits in one compiled grid; consumed by
        # _erase_fault_retire only when ctx.with_faults). endurance_limit
        # INT_MAX = the worn regime is unreachable for this drive.
        "fault_rate": jnp.asarray(ctx.mcfg.fault_rate, jnp.float32),
        "fault_rate_worn": jnp.asarray(ctx.mcfg.fault_rate_worn, jnp.float32),
        "endurance_limit": jnp.asarray(
            ctx.mcfg.endurance_pe_limit
            if ctx.mcfg.endurance_pe_limit > 0 else INT_MAX,
            jnp.int32,
        ),
        "fault_seed": jnp.asarray(
            ctx.mcfg.fault_seed & 0xFFFFFFFF, jnp.uint32
        ),
    }


# ---------------------------------------------------------------------------
# select-dieted conditionals
# ---------------------------------------------------------------------------
#
# Under vmap a lax.cond lowers to a select over its OUTPUTS; a branch that
# returns the whole SimState therefore copies all ~29 state arrays per
# step per lane — including the [G, bits] bloom filter pair that GC never
# writes. Every per-step conditional below routes through _cond_fields /
# _while_fields, which carry ONLY the fields the true branch can modify;
# the rest ride through the enclosing closure untouched. Under plain jit
# this is the same real branch either way.

# every field any GC drain (bulk or reference) can touch
_GC_FIELDS = (
    "page_map", "slot_lba", "valid", "live", "fill", "stamp", "state",
    "group_of", "active_blk", "grp_size", "grp_live", "grp_phys",
    "grp_surplus", "free_blocks", "mapped_pages", "clock", "n_mig",
    "n_dropped", "n_erase",
    # wear layer: every drain bumps the victim's P-E count + the carried
    # aggregates and clears its trimmed-slot tally
    "erase_count", "trim_dead", "erase_total", "erase_sq_total",
)
# extra fields the post-erase fault hook (_erase_fault_retire) can touch —
# appended to _GC_FIELDS at every drain cond/while ONLY in with_faults
# contexts, so fault-free steps keep their exact select set
_FAULT_FIELDS = (
    "retired_blocks", "spares_left", "grp_retired", "drive_status",
    "degraded_at", "n_erase_fail", "fault_draws",
)


def _gc_fields(ctx: SimContext):
    """The drain-cond field set: _GC_FIELDS, plus the fault hook's fields
    when the context injects faults (every erase site shares this)."""
    return _GC_FIELDS + (_FAULT_FIELDS if ctx.with_faults else ())
# fields the in-write block allocation (_pop_free_block + seal) can touch
_ALLOC_FIELDS = (
    "state", "group_of", "fill", "grp_phys", "grp_surplus", "free_blocks",
    "stamp", "clock", "active_blk",
)
# fields the §5.1 interval update (EWMA + create/merge + re-allocation)
# can touch — group stats plus the block relabel/seal of a merge
_INTERVAL_FIELDS = (
    "grp_p", "grp_writes", "interval", "cooldown", "grp_active",
    "grp_size", "grp_live", "grp_phys", "grp_alloc", "grp_surplus",
    "grp_created", "group_of", "state", "active_blk",
)
# everything the post-target-selection write step (fast append OR the whole
# heavy tail) can touch: all state except the bloom filter triple, which
# only target selection writes
_STEP_FIELDS = tuple(
    f for f in SimState.__dataclass_fields__
    if f not in ("bloom_active", "bloom_passive", "bloom_writes")
)
# the op-stream WRITE/TRIM dispatch selects over everything: the write
# branch contains target selection, which owns the bloom triple
_OP_FIELDS = tuple(SimState.__dataclass_fields__)


def _fields_of(st: SimState, fields):
    return tuple(getattr(st, f) for f in fields)


def _cond_fields(pred, fn, st: SimState, fields):
    """``st if not pred else fn(st)``, selecting only over ``fields``.

    ``fn`` must not modify any field outside ``fields`` (the others are
    silently dropped from its result — keep the lists exhaustive).
    """
    out = jax.lax.cond(
        pred,
        lambda s: _fields_of(fn(s), fields),
        lambda s: _fields_of(s, fields),
        st,
    )
    return st.replace(**dict(zip(fields, out)))


def _while_fields(cond_fn, body_fn, st: SimState, extra, fields):
    """A bounded while_loop whose carry is (fields-of-st, extra) instead of
    the whole state — fields outside ``fields`` must be loop-invariant.
    cond_fn/body_fn take and return (full-state, extra)."""

    def rebuild(carry):
        vals, extra = carry
        return st.replace(**dict(zip(fields, vals))), extra

    def cond(carry):
        return cond_fn(*rebuild(carry))

    def body(carry):
        s2, e2 = body_fn(*rebuild(carry))
        return _fields_of(s2, fields), e2

    vals, extra = jax.lax.while_loop(
        cond, body, (_fields_of(st, fields), extra)
    )
    return st.replace(**dict(zip(fields, vals))), extra


# ---------------------------------------------------------------------------
# primitive state updates
# ---------------------------------------------------------------------------

def _pop_free_block(st: SimState, g):
    """Claim a FREE block for group g (becomes its OPEN active block)."""
    free_mask = st.state == FREE
    blk = jnp.argmax(free_mask)  # reserve logic upstream guarantees ≥1
    ok = free_mask[blk]
    d = jnp.where(ok, 1, 0)
    grp_phys = st.grp_phys.at[g].add(d)
    st = st.replace(
        state=st.state.at[blk].set(jnp.where(ok, OPEN, st.state[blk])),
        group_of=st.group_of.at[blk].set(jnp.where(ok, g, st.group_of[blk])),
        fill=st.fill.at[blk].set(jnp.where(ok, 0, st.fill[blk])),
        grp_phys=grp_phys,
        grp_surplus=surplus_of(st.grp_active, grp_phys, st.grp_alloc),
        free_blocks=st.free_blocks - d,
        # LRU clock: a block's age is its claim time — "least recently
        # erased" degenerates into cleaning freshly-filled (never-erased)
        # blocks if ages only advance on erase.
        stamp=st.stamp.at[blk].set(jnp.where(ok, st.clock, st.stamp[blk])),
        clock=st.clock + d,
    )
    return st, blk, ok


def _write_page(ctx: SimContext, st: SimState, lba, g, *, is_migration: bool,
                enabled=True):
    """Append page `lba` to group g's active block (allocating if needed).

    enabled: traced mask — when False every update is an elementwise no-op.
    The reference GC drain uses this instead of wrapping the call in
    lax.cond, which under vmap would select over the whole state pytree per
    page.
    """
    b = ctx.geom.pages_per_block
    blk = st.active_blk[g]
    blk_full = jnp.where(blk >= 0, st.fill[jnp.maximum(blk, 0)] >= b, True)

    def alloc(st):
        old = st.active_blk[g]
        # seal the previous active block
        st = st.replace(
            state=st.state.at[jnp.maximum(old, 0)].set(
                jnp.where(old >= 0, CLOSED, st.state[jnp.maximum(old, 0)])
            )
        )
        st, new_blk, ok = _pop_free_block(st, g)
        return st.replace(
            active_blk=st.active_blk.at[g].set(jnp.where(ok, new_blk, old))
        )

    st = _cond_fields(blk_full & enabled, alloc, st, _ALLOC_FIELDS)
    blk = st.active_blk[g]
    slot = st.fill[blk]
    # overflow guard: if the pool was empty the active block may still be
    # full — drop the write and count it (tests assert this never fires).
    ok = enabled & (blk >= 0) & (slot < b)
    blk_c = jnp.maximum(blk, 0)
    slot_c = jnp.minimum(slot, b - 1)
    updates = dict(
        fill=st.fill.at[blk_c].add(jnp.where(ok, 1, 0)),
        slot_lba=st.slot_lba.at[blk_c, slot_c].set(
            jnp.where(ok, lba, st.slot_lba[blk_c, slot_c])
        ),
        valid=st.valid.at[blk_c, slot_c].set(
            jnp.where(ok, True, st.valid[blk_c, slot_c])
        ),
        live=st.live.at[blk_c].add(jnp.where(ok, 1, 0)),
        # a FAILED (enabled but not ok) write unmaps the page; a disabled
        # call must leave the mapping untouched
        page_map=st.page_map.at[lba].set(
            jnp.where(ok, blk * b + slot,
                      jnp.where(enabled, -1, st.page_map[lba]))
        ),
        grp_size=st.grp_size.at[g].add(jnp.where(ok, 1, 0)),
        grp_live=st.grp_live.at[g].add(jnp.where(ok, 1, 0)),
        mapped_pages=st.mapped_pages + jnp.where(ok, 1, 0),
        n_dropped=st.n_dropped + jnp.where(ok | jnp.logical_not(enabled), 0, 1),
    )
    if is_migration:
        updates["n_mig"] = st.n_mig + jnp.where(ok, 1, 0)
    return st.replace(**updates)


def _invalidate(ctx: SimContext, st: SimState, lba):
    b = ctx.geom.pages_per_block
    pm = st.page_map[lba]
    has = pm >= 0
    pm_c = jnp.maximum(pm, 0)
    blk_c = pm_c // b
    slot = pm_c % b
    old_g = st.group_of[blk_c]
    d_g = jnp.where(has & (old_g >= 0), -1, 0)
    st = st.replace(
        valid=st.valid.at[blk_c, slot].set(
            jnp.where(has, False, st.valid[blk_c, slot])
        ),
        live=st.live.at[blk_c].add(jnp.where(has, -1, 0)),
        grp_size=st.grp_size.at[jnp.maximum(old_g, 0)].add(d_g),
        grp_live=st.grp_live.at[jnp.maximum(old_g, 0)].add(d_g),
        mapped_pages=st.mapped_pages + jnp.where(has, -1, 0),
    )
    return st, jnp.where(has, old_g, 0)


def _invalidate_counts(ctx: SimContext, st: SimState, lba):
    """The counter half of :func:`_invalidate`: live/grp_size decrements and
    the old-group lookup, WITHOUT the valid-bit clear.

    The fast-path step defers the clear into the fused ``write_path`` op
    (heavy steps apply it via :func:`_clear_valid` before any GC runs).
    Nothing between here and there reads ``valid`` — target selection only
    touches group stats and the bloom pair — so the split is exact.
    Returns (st, old_g, old_pm).
    """
    b = ctx.geom.pages_per_block
    pm = st.page_map[lba]
    has = pm >= 0
    pm_c = jnp.maximum(pm, 0)
    old_g = st.group_of[pm_c // b]
    d_g = jnp.where(has & (old_g >= 0), -1, 0)
    st = st.replace(
        live=st.live.at[pm_c // b].add(jnp.where(has, -1, 0)),
        grp_size=st.grp_size.at[jnp.maximum(old_g, 0)].add(d_g),
        grp_live=st.grp_live.at[jnp.maximum(old_g, 0)].add(d_g),
        mapped_pages=st.mapped_pages + jnp.where(has, -1, 0),
    )
    return st, jnp.where(has, old_g, 0), pm


def _clear_valid(ctx: SimContext, st: SimState, pm):
    """Complete a deferred invalidate: clear the old slot's valid bit."""
    b = ctx.geom.pages_per_block
    has = pm >= 0
    pm_c = jnp.maximum(pm, 0)
    blk_c, slot = pm_c // b, pm_c % b
    return st.replace(
        valid=st.valid.at[blk_c, slot].set(
            jnp.where(has, False, st.valid[blk_c, slot])
        )
    )


# ---------------------------------------------------------------------------
# fault injection / bad-block retirement
# ---------------------------------------------------------------------------

def _fault_uniform(seed, n):
    """Counter-based uniform in [0, 1): murmur3's fmix32 finalizer over
    (seed, draw index). The top 24 hash bits map to an exactly-representable
    float32 in [0, 1 - 2^-24], so ``u < rate`` is never perturbed by
    rounding at either endpoint: rate 0 fails nothing, rate 1 fails
    everything. Counter-based (the draw index is carried state) so the
    fault stream is a pure function of (seed, #erases so far) — replayable,
    order-independent of everything else the step does."""
    h = seed + n * jnp.uint32(2654435761)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return (h >> 8).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def _erase_fault_retire(ctx: SimContext, st: SimState, victim, g, policy):
    """Retry-then-retire fault hook, applied to a drain's OUTPUT state
    (the victim is already erased: FREE, wear counters bumped).

    One uniform decides the whole retry ladder: the erase attempt fails
    iff ``u < rate`` and all ``1 + erase_max_retries`` attempts fail iff
    ``u < rate^(1+retries)`` — retire ⊂ fail by construction, so a single
    draw covers both and a zero-rate drive consumes the identical (empty)
    event set. ``rate`` is the per-drive base ``fault_rate`` until the
    victim's P-E count crosses the per-drive ``endurance_limit``, then
    ``fault_rate_worn`` (default 1.0: deterministic death at the limit).

    On retire the erase is UNDONE from the wear accounting (a failed erase
    completes no P-E cycle) and the block leaves circulation: state
    RETIRED, group label restored (``grp_retired`` tracks labels through
    §5.2 merges), the pool gives back the block it just reclaimed, one
    spare is drawn. If the spare pool was already dry, the drive degrades
    instead of violating the pool invariants: ``drive_status`` flips to
    STATUS_DEGRADED and every later op freezes (make_step's halt guard).
    """
    if not ctx.with_faults:
        return st
    ec_new = st.erase_count[victim]  # post-bump P-E count of this erase
    worn = (ec_new - 1) >= policy["endurance_limit"]
    rate = jnp.where(
        worn,
        jnp.maximum(policy["fault_rate_worn"], policy["fault_rate"]),
        policy["fault_rate"],
    )
    u = _fault_uniform(policy["fault_seed"], st.fault_draws)
    failed = u < rate
    retired = u < rate ** (1 + ctx.mcfg.erase_max_retries)
    d = jnp.where(retired, 1, 0)
    spares0 = st.spares_left
    # Death has two doors. (1) Spares exhausted: the accounting margin
    # that keeps effective OP positive is gone. (2) Pool death: a
    # retiring GC nets ZERO free blocks (drain +1, retire -1), so heavy
    # retirement can drain the pool to empty — and at free_blocks == 0
    # no GC can ever run again (_gc_one needs ≥ 1 for migration
    # headroom): the drive is operationally dead even with spares left.
    # Either way we freeze instead of silently dropping writes.
    free_after = st.free_blocks - d
    degrade = (
        retired
        & (st.drive_status == STATUS_OK)
        & ((spares0 <= 0) | (free_after <= 0))
    )
    return st.replace(
        state=st.state.at[victim].set(
            jnp.where(retired, RETIRED, st.state[victim]).astype(
                st.state.dtype
            )
        ),
        group_of=st.group_of.at[victim].set(
            jnp.where(retired, jnp.asarray(g, jnp.int32),
                      st.group_of[victim])
        ),
        free_blocks=free_after,
        # a failed erase completes no P-E cycle: undo the drain's bump
        # (e_old = ec_new - 1; Σe² loses (e_old+1)² − e_old²)
        erase_count=st.erase_count.at[victim].add(-d),
        erase_total=st.erase_total - d,
        erase_sq_total=st.erase_sq_total - d * (2 * (ec_new - 1) + 1),
        n_erase=st.n_erase - d,
        retired_blocks=st.retired_blocks + d,
        grp_retired=st.grp_retired.at[g].add(d),
        spares_left=jnp.maximum(spares0 - d, 0),
        n_erase_fail=st.n_erase_fail + jnp.where(failed, 1, 0),
        drive_status=jnp.where(
            degrade, STATUS_DEGRADED, st.drive_status
        ).astype(jnp.int32),
        degraded_at=jnp.where(
            degrade & (st.degraded_at < 0), st.n_app, st.degraded_at
        ).astype(jnp.int32),
        fault_draws=st.fault_draws + jnp.uint32(1),
    )


# ---------------------------------------------------------------------------
# garbage collection (one victim) — §5.4
# ---------------------------------------------------------------------------

# the emergency valve's fixed weight point: pure greedy reclaim
GC_W_GREEDY = (1.0, 0.0, 0.0, 0.0)


def _select_victim(ctx: SimContext, st: SimState, g, gc_w):
    """Multi-objective victim selection: one traced score, maximised.

        S(blk) = α·(B − live) − γ·stamp − β·erase_count − τ·trim_dead

    over CLOSED blocks of group g (others masked to -inf). Every term is an
    int32 counter cast to float32 — exact below 2^24, far beyond any test
    horizon — so the legacy policies are EXACT weight points with the same
    first-index tie-break as the argmin they replace: greedy = (1,0,0,0)
    (argmax of B − live ≡ argmin of live), lru = (0,0,1,0) (argmin of
    stamp). β > 0 steers GC away from high-P-E blocks (wear leveling);
    τ > 0 deprioritises blocks rich in trimmed-but-unerased slots. This
    stays the only full block-array reduction on the write path.
    """
    closed = (st.state == CLOSED) & (st.group_of == g)
    b = ctx.geom.pages_per_block
    alpha, beta, gamma, tau = gc_w[0], gc_w[1], gc_w[2], gc_w[3]
    score = (
        alpha * (b - st.live).astype(jnp.float32)
        - gamma * st.stamp.astype(jnp.float32)
        - beta * st.erase_count.astype(jnp.float32)
        - tau * st.trim_dead.astype(jnp.float32)
    )
    victim = jnp.argmax(jnp.where(closed, score, -jnp.inf))
    # a fully-live victim frees nothing: skip unless the policy is
    # age-driven (γ > 0 — LRU must clean stale blocks even when full;
    # the old gc_lru boolean guard, generalised)
    age_driven = gamma > 0.0
    ok = closed[victim] & (age_driven | (st.live[victim] < b))
    return victim, ok


def _gc_drain_bulk(ctx: SimContext, st: SimState, victim, g, policy, rate_fn):
    """Vectorized victim drain: migrate every live page in one shot.

    Elementwise-identical to :func:`_gc_drain_reference` whenever no write
    is dropped mid-drain (the pool-reserve invariant callers maintain;
    tests assert ``n_dropped == 0``). The only sequential remnant is the
    demotion decision below — everything that lands state is a chunked
    gather/scatter.
    """
    b = ctx.geom.pages_per_block
    k = ctx.geom.n_blocks
    g_max = st.grp_active.shape[0]
    lba_pages = st.page_map.shape[0]
    g32 = jnp.asarray(g, jnp.int32)

    lbas = st.slot_lba[victim]            # [B]; dead slots hold -1
    is_live = st.valid[victim]            # [B]
    lbas_c = jnp.maximum(lbas, 0)
    n_live = jnp.sum(is_live)

    # -- per-slot DEMOTION FLAGS (§5.6), vectorized over the victim's lanes.
    # A GC demotion only ever moves a page one group colder, and whether a
    # page is demotion-eligible depends solely on drain-invariant state
    # (oracle rates, fdp bands, the bloom filter pair) — so it precomputes
    # as one [B] mask, for the trace-time-dispatched detector only (every
    # compiled step has exactly one). Keeping the big state arrays out of
    # the per-slot machinery below matters: anything a lax.scan touches is
    # hauled through the loop boundary every iteration on XLA:CPU.
    td = ctx.mcfg.td_mode
    if td == "fdp" and ctx.can_demote:
        r = jax.vmap(lambda l: rate_fn(st, l))(lbas_c)
        demote_flag = r < 0.5 * policy["fdp_rate"][g]
    elif td == "bloom" and ctx.can_demote:
        in_a = jax.vmap(
            lambda l: _bloom_query(ctx, st.bloom_active, l, g)
        )(lbas_c)
        in_p = jax.vmap(
            lambda l: _bloom_query(ctx, st.bloom_passive, l, g)
        )(lbas_c)
        demote_flag = ~in_a & ~in_p
    else:
        # static detector: pages never change temperature during GC, so
        # the whole flags/targets machinery below is structurally absent
        demote_flag = jnp.zeros(b, bool)

    # -- per-slot target groups, exact sequential semantics. A demoted page
    # lands one group colder BY CURRENT HIT-RATE ORDER, and hit rates
    # (grp_p / grp_size) drift as the drain itself moves pages — so when any
    # page is flagged, a lax.scan carrying ONLY the [G] group sizes replays
    # the per-page neighbor decisions bit-exactly. The common case (static
    # detector / nothing flagged) short-circuits to constant targets.
    grp_p, grp_active = st.grp_p, st.grp_active

    def const_targets(_):
        return jnp.full(b, g32)

    arange_g = jnp.arange(g_max, dtype=jnp.int32)

    def scan_targets(_):
        def body(gs, xs):
            flag, live = xs
            # _hit_rates over the drifted sizes, [G]-sized; the
            # next-colder neighbor comes from the shared reduction helper
            # (== _sgv_neighbors' stable argsort; no sort — a batched
            # XLA:CPU sort 16×/drain dominates the drain)
            hr = jnp.where(
                grp_active,
                grp_p / jnp.maximum(gs.astype(jnp.float32), 1.0),
                -1.0,
            )
            nb = _neighbor_colder(hr, grp_active, g32, g_known_active=True)
            t = jnp.where(flag & live, nb, g32).astype(jnp.int32)
            gs = gs.at[g].add(jnp.where(live, -1, 0)).at[t].add(
                jnp.where(live, 1, 0)
            )
            return gs, t

        # full unroll: B is small and static; the scan-loop overhead on
        # XLA:CPU would otherwise dominate the tiny [G]-sized body.
        # The carry is the EFFECTIVE sizes (grp_live, what _hit_rates
        # reads); identical drift to grp_size within a drain.
        _, ts = jax.lax.scan(
            body, st.grp_live, (demote_flag, is_live), unroll=b
        )
        return ts

    if ctx.can_demote:
        targets = jax.lax.cond(
            jnp.any(demote_flag & is_live), scan_targets, const_targets, 0
        )
    else:
        targets = const_targets(0)
    t_live = jnp.where(is_live, targets, g_max)  # dead rows → masked out

    # NOTE on lowering: XLA:CPU's scatter expander rewrites every multi-row
    # .at[] scatter into a while loop (measured: ~14 scatters/drain → ~40
    # extra loops, ~70µs, 4× the whole drain). Group/block-sized updates
    # below therefore use DENSE one-hot masked ops ([b,G]/[G,K]/[b,K] —
    # tiny, they fuse); only the two capacity-sized updates (page_map and
    # the compact_slots pool copy) stay 1-D scatters, where ONE expanded
    # loop per drain beats a capacity-wide mask. Scalar-index updates (the
    # victim erase) lower to dynamic-update-slice and are free either way.
    arange_k = jnp.arange(k, dtype=jnp.int32)
    idx = jnp.arange(b, dtype=jnp.int32)

    # -- segment-count pages per target group; claim fresh blocks up front.
    # A victim holds ≤ B live pages, so each target group claims at most ONE
    # fresh block per drain; the i-th claim (ordered by the slot position of
    # the first non-fitting page) takes the i-th lowest-index FREE block —
    # exactly what the sequential argmax-pop produces.
    onehot_t = t_live[:, None] == arange_g[None, :]  # [b, G], live rows only
    m = jnp.sum(onehot_t, axis=0, dtype=jnp.int32)   # pages per target group
    ab = st.active_blk
    has_ab = ab >= 0
    ab_c = jnp.maximum(ab, 0)
    fill_ab = jnp.where(has_ab, st.fill[ab_c], b)
    space = b - jnp.minimum(fill_ab, b)   # free slots in the active block
    claim = m > space                     # group needs a fresh block
    seal = claim & has_ab                 # …sealing its current active

    # within-group rank of each live page, in slot order
    same = (
        (targets[:, None] == targets[None, :])
        & is_live[None, :] & is_live[:, None]
    )
    rank = jnp.sum(same & (idx[None, :] < idx[:, None]), axis=1)

    is_claim_pg = is_live & (rank == space[targets])
    claim_pos = jnp.min(
        jnp.where(onehot_t & is_claim_pg[:, None], idx[:, None], INT_MAX),
        axis=0,
    )  # [G] slot position of each group's claim
    claim_rank = jnp.sum(
        claim[None, :] & (claim_pos[None, :] < claim_pos[:, None]), axis=1
    )
    free_mask = st.state == FREE
    n_free = st.free_blocks  # carried scalar == sum(free_mask), invariant
    # free_by_rank[r] = r-th lowest FREE block index (what the sequential
    # argmax-pop hands out); an XLA:CPU sort here would cost ~100µs/drain
    frank = jnp.cumsum(free_mask) - 1  # free-rank of each free block
    free_by_rank = jnp.min(
        jnp.where(
            free_mask[None, :] & (frank[None, :] == arange_g[:, None]),
            arange_k[None, :], k,
        ),
        axis=1,
    )  # [G]
    claim_ok = claim & (claim_rank < n_free)  # pool-exhausted claims fail
    new_blk = jnp.where(
        claim_ok, free_by_rank[jnp.minimum(claim_rank, g_max - 1)], -1
    )

    # -- per-page destinations ---------------------------------------------
    space_p = space[targets]
    in_old = rank < space_p
    dst_blk = jnp.where(in_old, ab_c[targets], new_blk[targets])
    dst_slot = jnp.where(in_old, fill_ab[targets] + rank, rank - space_p)
    ok = is_live & (in_old | claim_ok[targets])
    db = jnp.where(ok, dst_blk, k)        # masked rows land nowhere

    # -- seal / claim bookkeeping ------------------------------------------
    seal_mask = jnp.any(
        (ab_c[None, :] == arange_k[:, None]) & seal[None, :], axis=1
    )  # [K]
    claim_onehot = (
        (new_blk[None, :] == arange_k[:, None]) & claim_ok[None, :]
    )  # [K, G]
    claim_mask = jnp.any(claim_onehot, axis=1)
    state_a = jnp.where(seal_mask, CLOSED, st.state)
    state_a = jnp.where(claim_mask, OPEN, state_a)
    group_of = jnp.where(
        claim_mask, jnp.sum(claim_onehot * arange_g[None, :], axis=1),
        st.group_of,
    )
    stamp = jnp.where(
        claim_mask,
        jnp.sum(claim_onehot * (st.clock + claim_rank)[None, :], axis=1),
        st.stamp,
    )
    n_claimed = jnp.sum(claim_ok)
    clock = st.clock + n_claimed
    grp_phys = st.grp_phys + claim_ok.astype(jnp.int32)
    active_blk = jnp.where(claim_ok, new_blk, ab)

    # -- land the pages (dense chunked writes) ------------------------------
    dst_onehot = db[:, None] == arange_k[None, :]    # [b, K], ok rows only
    dst_count = jnp.sum(dst_onehot, axis=0, dtype=jnp.int32)
    fill_a = jnp.where(claim_mask, 0, st.fill) + dst_count
    live_a = st.live + dst_count
    # the slot-content copy (victim slots → destination slots) is the GC
    # kernel's move list: Pallas-backed on TPU, dense one-hot writes off-TPU
    slot_lba, valid = compact_slots(
        st.slot_lba, st.valid,
        jnp.where(ok, victim, -1), idx, db, dst_slot,
    )
    # 1-D scatter, not a [b, LBA] one-hot: a dense mask here would scale
    # with drive capacity, and a single expanded scatter loop per site is
    # measurably cheaper than the capacity-wide mask even at test geometry
    page_map = st.page_map.at[jnp.where(is_live, lbas_c, lba_pages)].set(
        jnp.where(ok, dst_blk * b + dst_slot, -1), mode="drop"
    )  # dead slots land out of bounds → untouched
    landed = jnp.sum(onehot_t & ok[:, None], axis=0, dtype=jnp.int32)
    grp_size = st.grp_size.at[g].add(-n_live) + landed
    grp_live_a = st.grp_live.at[g].add(-n_live) + landed
    n_lost = jnp.sum(is_live & jnp.logical_not(ok))  # dropped migrations

    # -- erase the victim ---------------------------------------------------
    grp_phys_f = grp_phys.at[g].add(-1)
    e_old = st.erase_count[victim]
    return st.replace(
        state=state_a.at[victim].set(FREE),
        group_of=group_of.at[victim].set(-1),
        fill=fill_a.at[victim].set(0),
        live=live_a.at[victim].set(0),
        slot_lba=slot_lba.at[victim].set(-1),
        valid=valid.at[victim].set(False),
        stamp=stamp.at[victim].set(clock),
        clock=clock + 1,
        grp_phys=grp_phys_f,
        grp_surplus=surplus_of(st.grp_active, grp_phys_f, st.grp_alloc),
        free_blocks=st.free_blocks - n_claimed + 1,
        mapped_pages=st.mapped_pages - n_lost,
        active_blk=active_blk,
        page_map=page_map,
        grp_size=grp_size,
        grp_live=grp_live_a,
        n_mig=st.n_mig + jnp.sum(ok),
        n_dropped=st.n_dropped + n_lost,
        n_erase=st.n_erase + 1,
        # wear: one more P-E cycle on the victim; Σe² gains (e+1)² − e²
        erase_count=st.erase_count.at[victim].add(1),
        trim_dead=st.trim_dead.at[victim].set(0),
        erase_total=st.erase_total + 1,
        erase_sq_total=st.erase_sq_total + 2 * e_old + 1,
    )


def _gc_drain_bulk_static(ctx: SimContext, st: SimState, victim, g):
    """Single-target specialization of :func:`_gc_drain_bulk` for
    static-detector contexts (``ctx.can_demote=False``).

    Every live page lands back in group g, so the per-target-group claim
    machinery ([b,G] one-hots, free-rank assignment, [K]-wide seal/claim
    masks) collapses to scalars: at most ONE fresh block is claimed (the
    lowest-index FREE block — what the sequential pop hands out) and every
    block-sized update is a masked single-index store. Elementwise-
    identical to the general drain with constant targets, which the
    bulk-vs-reference equivalence suite asserts for every static manager.
    """
    b = ctx.geom.pages_per_block
    k = ctx.geom.n_blocks
    lba_pages = st.page_map.shape[0]

    lbas = st.slot_lba[victim]            # [B]; dead slots hold -1
    is_live = st.valid[victim]            # [B]
    lbas_c = jnp.maximum(lbas, 0)
    n_live = jnp.sum(is_live)
    live_i = is_live.astype(jnp.int32)
    rank = jnp.cumsum(live_i) - live_i    # live-rank of each slot

    ab = st.active_blk[g]
    has_ab = ab >= 0
    ab_c = jnp.maximum(ab, 0)
    fill_ab = jnp.where(has_ab, st.fill[ab_c], b)
    space = b - jnp.minimum(fill_ab, b)   # free slots in the active block
    claim = n_live > space
    seal = claim & has_ab

    new_blk = jnp.argmax(st.state == FREE)  # lowest-index FREE block
    claim_ok = claim & (st.free_blocks >= 1)
    new_c = jnp.where(claim_ok, new_blk, 0)

    # -- per-page destinations ---------------------------------------------
    in_old = rank < space
    dst_blk = jnp.where(in_old, ab_c, new_c)
    dst_slot = jnp.where(in_old, fill_ab + rank, rank - space)
    ok = is_live & (in_old | claim_ok)
    db = jnp.where(ok, dst_blk, k)        # masked rows land nowhere
    n_old = jnp.minimum(n_live, space)
    n_new = jnp.where(claim_ok, n_live - n_old, 0)
    n_ok = n_old + n_new

    # -- seal / claim bookkeeping (all scalar-index stores) -----------------
    state_a = st.state.at[ab_c].set(
        jnp.where(seal, CLOSED, st.state[ab_c])
    )
    state_a = state_a.at[new_c].set(
        jnp.where(claim_ok, OPEN, state_a[new_c])
    )
    group_of = st.group_of.at[new_c].set(
        jnp.where(claim_ok, g, st.group_of[new_c])
    )
    stamp = st.stamp.at[new_c].set(
        jnp.where(claim_ok, st.clock, st.stamp[new_c])
    )
    clock = st.clock + jnp.where(claim_ok, 1, 0)
    fill_a = st.fill.at[ab_c].add(jnp.where(has_ab, n_old, 0))
    fill_a = fill_a.at[new_c].set(
        jnp.where(claim_ok, n_new, fill_a[new_c])
    )
    live_a = st.live.at[ab_c].add(jnp.where(has_ab, n_old, 0))
    live_a = live_a.at[new_c].add(jnp.where(claim_ok, n_new, 0))
    active_blk = st.active_blk.at[g].set(jnp.where(claim_ok, new_blk, ab))

    # -- land the pages -----------------------------------------------------
    idx = jnp.arange(b, dtype=jnp.int32)
    slot_lba, valid = compact_slots(
        st.slot_lba, st.valid,
        jnp.where(ok, victim, -1), idx, db, dst_slot,
    )
    page_map = st.page_map.at[jnp.where(is_live, lbas_c, lba_pages)].set(
        jnp.where(ok, dst_blk * b + dst_slot, -1), mode="drop"
    )  # dead slots land out of bounds → untouched

    # -- erase the victim ---------------------------------------------------
    # +1 physical block if one was claimed, -1 for the erased victim
    grp_phys = st.grp_phys.at[g].add(jnp.where(claim_ok, 0, -1))
    e_old = st.erase_count[victim]
    return st.replace(
        state=state_a.at[victim].set(FREE),
        group_of=group_of.at[victim].set(-1),
        fill=fill_a.at[victim].set(0),
        live=live_a.at[victim].set(0),
        slot_lba=slot_lba.at[victim].set(-1),
        valid=valid.at[victim].set(False),
        stamp=stamp.at[victim].set(clock),
        clock=clock + 1,
        grp_phys=grp_phys,
        grp_surplus=surplus_of(st.grp_active, grp_phys, st.grp_alloc),
        free_blocks=st.free_blocks - jnp.where(claim_ok, 1, 0) + 1,
        mapped_pages=st.mapped_pages - (n_live - n_ok),
        active_blk=active_blk,
        page_map=page_map,
        grp_size=st.grp_size.at[g].add(n_ok - n_live),
        grp_live=st.grp_live.at[g].add(n_ok - n_live),
        n_mig=st.n_mig + n_ok,
        n_dropped=st.n_dropped + (n_live - n_ok),
        n_erase=st.n_erase + 1,
        erase_count=st.erase_count.at[victim].add(1),
        trim_dead=st.trim_dead.at[victim].set(0),
        erase_total=st.erase_total + 1,
        erase_sq_total=st.erase_sq_total + 2 * e_old + 1,
    )


def _gc_drain_reference(ctx: SimContext, st: SimState, victim, g, demote_fn):
    """The pre-refactor per-page drain (16-step fori of single-page writes).

    Kept as the equivalence oracle for :func:`_gc_drain_bulk`
    (tests/test_bulk_gc.py); never on the default path.
    """
    b = ctx.geom.pages_per_block

    def body(j, st):
        # masked migration (no lax.cond: under vmap a per-slot cond would
        # select over the whole state pytree B×/GC)
        lba = st.slot_lba[victim, j]
        is_live = st.valid[victim, j]
        lba_c = jnp.maximum(lba, 0)  # dead slots hold -1
        st = st.replace(
            valid=st.valid.at[victim, j].set(
                jnp.where(is_live, False, st.valid[victim, j])
            ),
            live=st.live.at[victim].add(jnp.where(is_live, -1, 0)),
        )
        g_tgt = demote_fn(st, lba_c, g)  # pure read of st
        d = jnp.where(is_live, -1, 0)
        st = st.replace(
            grp_size=st.grp_size.at[g].add(d),
            grp_live=st.grp_live.at[g].add(d),
            mapped_pages=st.mapped_pages + d,
        )
        return _write_page(
            ctx, st, lba_c, g_tgt, is_migration=True, enabled=is_live
        )

    st = jax.lax.fori_loop(0, b, body, st)
    # erase
    grp_phys = st.grp_phys.at[g].add(-1)
    e_old = st.erase_count[victim]
    return st.replace(
        state=st.state.at[victim].set(FREE),
        group_of=st.group_of.at[victim].set(-1),
        fill=st.fill.at[victim].set(0),
        live=st.live.at[victim].set(0),
        slot_lba=st.slot_lba.at[victim].set(-1),
        valid=st.valid.at[victim].set(False),
        stamp=st.stamp.at[victim].set(st.clock),
        clock=st.clock + 1,
        grp_phys=grp_phys,
        grp_surplus=surplus_of(st.grp_active, grp_phys, st.grp_alloc),
        free_blocks=st.free_blocks + 1,
        n_erase=st.n_erase + 1,
        erase_count=st.erase_count.at[victim].add(1),
        trim_dead=st.trim_dead.at[victim].set(0),
        erase_total=st.erase_total + 1,
        erase_sq_total=st.erase_sq_total + 2 * e_old + 1,
    )


def _gc_one(ctx: SimContext, st: SimState, g, policy, rate_fn, gc_w,
            enabled=True):
    """GC one victim in group g; migrate live pages via the bulk drain.

    rate_fn(st, lba) -> the page's true update rate (oracle detector input);
    must be a pure function of drain-invariant data (it is: oracle arrays
    are indexed by lba/phase only). The §5.6 demotion rule itself is
    derived from ``policy`` — see _gc_drain_bulk / _target_group_gc.

    gc_w: the traced (α, β, γ, τ) victim-score weights (see
    :func:`_select_victim`); callers pass ``policy["gc_w"]`` or a fixed
    point like :data:`GC_W_GREEDY`.

    enabled: the caller's firing predicate, folded into the ONE dieted
    drain cond here instead of a second full-state cond at the call site
    (victim selection is a pair of [K] reductions, cheap to run masked).
    """
    assert ctx.gc_impl in ("bulk", "reference"), ctx.gc_impl
    victim, ok = _select_victim(ctx, st, g, gc_w)
    # migrations may need one fresh block beyond the active's free slots:
    # never start a GC with an empty pool (callers keep it ≥ 2).
    ok = ok & (st.free_blocks >= 1) & enabled
    if ctx.gc_impl == "bulk" and not ctx.can_demote:
        # static detector: every page lands back in g — the scalar-claim
        # specialization (no [b,G]/[K]-wide claim machinery per step)
        def drain(s):
            return _gc_drain_bulk_static(ctx, s, victim, g)
    elif ctx.gc_impl == "bulk":
        def drain(s):
            return _gc_drain_bulk(ctx, s, victim, g, policy, rate_fn)
    else:
        def demote_fn(s, l, gg):
            return _target_group_gc(ctx, s, l, gg, policy, rate_fn)

        def drain(s):
            return _gc_drain_reference(ctx, s, victim, g, demote_fn)

    if ctx.with_faults:
        # the fault hook runs on the drain OUTPUT (victim just erased),
        # inside this same dieted cond — no second full-state select
        base_drain = drain

        def drain(s):
            return _erase_fault_retire(ctx, base_drain(s), victim, g, policy)

    return _cond_fields(ok, drain, st, _gc_fields(ctx))


# ---------------------------------------------------------------------------
# over-provisioning allocation (interval) — §5.5
# ---------------------------------------------------------------------------

def _recompute_alloc(ctx: SimContext, st: SimState, policy):
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block
    active = st.grp_active
    # EFFECTIVE group sizes (carried grp_live == mapped pages per group;
    # == grp_size by construction — a trimmed page belongs to no group):
    # trimmed pages drop out of s, so op_total below grows by exactly the
    # trimmed span — TRIM is dynamic over-provisioning the §5.5 budgets
    # redistribute at the next interval (Frankie et al., arXiv:1208.1794).
    s = jnp.where(active, st.grp_live.astype(jnp.float32), 0.0)
    s = jnp.maximum(s, jnp.where(active, 1.0, 0.0))
    use_assumed = policy["alloc_mode"] == ALLOC_FDP
    p = jnp.where(
        active, jnp.where(use_assumed, policy["assumed_p"], st.grp_p), 0.0
    )
    p = p / jnp.maximum(p.sum(), 1e-9)
    # usable OP = spare pages beyond logical content, minus the GC reserve
    # and one block per active group (absorbs the per-group ceil slack so
    # the budgets can never collectively over-claim the pool)
    n_active = active.sum()
    op_total = (
        jnp.asarray(geom.pba_pages, jnp.float32)
        - (mcfg.gc_reserve_blocks + 1 + n_active) * b
        - s.sum()
    )
    if ctx.with_faults:
        # retired capacity leaves the OP budget: the allocator divides the
        # SHRUNKEN physical space, so predicted_wa()/model_error() track
        # the degraded geometry. Zero-retirement drives subtract exactly
        # 0.0. Budgets refresh at the next §5.1 interval (deliberate — an
        # eager realloc on retire would make the 80-iter closed-form
        # bisection a per-step select).
        op_total = op_total - st.retired_blocks.astype(jnp.float32) * b

    if ctx.use_closed_alloc:
        op_closed = allocate_closed_form(
            s, p, op_total,
            cold_rule=True,
            cold_hit_rate_frac=mcfg.cold_hit_rate_frac,
            cold_op_frac=mcfg.cold_op_frac,
        )
    else:
        # no drive in this context reads the closed form (is_closed is
        # identically False): skip its 80-iteration eq.-3 bisection
        op_closed = jnp.zeros_like(s)
    op_size = allocate_by_size(s, op_total)
    op_freq = allocate_by_frequency(p, op_total)
    is_closed = (policy["alloc_mode"] == ALLOC_CLOSED) | use_assumed
    is_freq = policy["alloc_mode"] == ALLOC_FREQ
    op = jnp.where(is_closed, op_closed, jnp.where(is_freq, op_freq, op_size))
    alloc_blocks = jnp.ceil((s + op) / b).astype(jnp.int32)
    alloc_blocks = jnp.where(active, jnp.maximum(alloc_blocks, 1), 0)
    return st.replace(
        grp_alloc=alloc_blocks,
        grp_surplus=surplus_of(active, st.grp_phys, alloc_blocks),
    )


def _interval_update(ctx: SimContext, st: SimState, policy):
    a = policy["ewma_a"]
    u = st.grp_writes.astype(jnp.float32) / policy["h"].astype(jnp.float32)
    active = st.grp_active
    st = st.replace(
        grp_p=jnp.where(active, st.grp_p * (1.0 - a) + a * u, 0.0),
        grp_writes=jnp.zeros_like(st.grp_writes),
        interval=st.interval + 1,
        cooldown=jnp.maximum(st.cooldown - 1, 0),
    )
    if ctx.use_dynamic:  # §5.2 create/merge: two argsorts per interval
        st = _maybe_create_or_merge(ctx, st, policy)
    st = _recompute_alloc(ctx, st, policy)
    return st


# ---------------------------------------------------------------------------
# group creation / merging (dynamic mode) — §5.2
# ---------------------------------------------------------------------------

def _hit_rates(st: SimState):
    # per EFFECTIVE (mapped) page — under TRIM a group's temperature is
    # measured over the pages it actually holds (grp_live, the carried
    # utilization counter; == grp_size, see its declaration)
    s = jnp.maximum(st.grp_live.astype(jnp.float32), 1.0)
    hr = st.grp_p / s
    return jnp.where(st.grp_active, hr, -1.0)


def _maybe_create_or_merge(ctx: SimContext, st: SimState, policy):
    mcfg = ctx.mcfg
    dynamic = policy["dynamic_groups"]
    f_min = policy["f_min_pages"]
    hr = _hit_rates(st)
    order = jnp.argsort(-hr)  # hottest first
    hottest, second = order[0], order[1]
    n_active = st.grp_active.sum()
    can_slot = n_active < policy["max_groups"]
    hot_ratio = hr[hottest] / jnp.maximum(hr[second], 1e-12)
    create = (
        dynamic
        & can_slot
        & (st.cooldown == 0)
        & (n_active >= 2)
        & (hot_ratio >= mcfg.q_create)
        & (st.grp_size[hottest] >= f_min)
    )

    def do_create(st):
        slot = jnp.argmin(st.grp_active)  # first inactive slot
        grp_active = st.grp_active.at[slot].set(True)
        grp_phys = st.grp_phys.at[slot].set(0)
        return st.replace(
            grp_active=grp_active,
            # seed stats: half the hottest group's measured frequency
            grp_p=st.grp_p.at[slot].set(st.grp_p[hottest] * 0.5),
            grp_size=st.grp_size.at[slot].set(0),
            grp_live=st.grp_live.at[slot].set(0),
            grp_phys=grp_phys,
            grp_surplus=surplus_of(grp_active, grp_phys, st.grp_alloc),
            grp_created=st.grp_created.at[slot].set(st.interval),
            cooldown=jnp.asarray(mcfg.w_intervals, jnp.int32),
        )

    st = _cond_fields(
        create, do_create, st,
        ("grp_active", "grp_p", "grp_size", "grp_live", "grp_phys",
         "grp_surplus", "grp_created", "cooldown"),
    )

    # merge: coldest adjacent pair that converged, or an undersized group
    hr = _hit_rates(st)
    order = jnp.argsort(-hr)
    n_active = st.grp_active.sum()
    # adjacent pair ratios in hit-rate order
    hr_sorted = hr[order]
    idx = jnp.arange(hr.shape[0])
    valid_pair = (idx + 1 < n_active)
    ratio = hr_sorted / jnp.maximum(jnp.roll(hr_sorted, -1), 1e-12)
    converged = valid_pair & (ratio < 1.3) & (hr_sorted > 0)
    tiny = valid_pair & (
        st.grp_size[order] < f_min
    ) & (jnp.roll(hr_sorted, -1) > 0)
    mergeable = converged | tiny
    pair_i = jnp.argmax(mergeable)
    do_merge = (
        dynamic & mergeable[pair_i] & (st.cooldown == 0) & (n_active > 2)
    )

    def merge(st):
        g_from = order[pair_i]          # hotter of the pair
        g_to = order[pair_i + 1]        # absorbed into the colder
        # relabel blocks (the paper: a merge is logical)
        group_of = jnp.where(st.group_of == g_from, g_to, st.group_of)
        # seal g_from's active block (no longer reachable)
        ab = st.active_blk[g_from]
        state_a = st.state.at[jnp.maximum(ab, 0)].set(
            jnp.where(ab >= 0, CLOSED, st.state[jnp.maximum(ab, 0)])
        )
        merged = {}
        # RETIRED blocks keep their group label, so a merge must move the
        # per-group retired counts along with the live/phys aggregates
        merge_keys = ("grp_size", "grp_live", "grp_phys", "grp_p",
                      "grp_writes")
        if ctx.with_faults:
            merge_keys = merge_keys + ("grp_retired",)
        for key in merge_keys:
            arr = getattr(st, key)
            merged[key] = arr.at[g_to].add(arr[g_from]).at[g_from].set(0)
        grp_active = st.grp_active.at[g_from].set(False)
        return st.replace(
            group_of=group_of,
            state=state_a,
            active_blk=st.active_blk.at[g_from].set(-1),
            grp_active=grp_active,
            grp_surplus=surplus_of(
                grp_active, merged["grp_phys"], st.grp_alloc
            ),
            cooldown=jnp.asarray(mcfg.w_intervals, jnp.int32),
            **merged,
        )

    merge_cond_fields = (
        "group_of", "state", "active_blk", "grp_active", "grp_surplus",
        "cooldown", "grp_size", "grp_live", "grp_phys", "grp_p",
        "grp_writes",
    )
    if ctx.with_faults:
        merge_cond_fields = merge_cond_fields + ("grp_retired",)
    return _cond_fields(do_merge, merge, st, merge_cond_fields)


# ---------------------------------------------------------------------------
# temperature detection — §5.6 (+ oracle modes for §6 experiments)
# ---------------------------------------------------------------------------

def _sgv_neighbors(st: SimState):
    """hotter_of[g], colder_of[g] by current hit-rate order (argsort form).

    Kept as the semantic oracle for the reduction-based
    :func:`_neighbor_hotter` / :func:`_neighbor_colder` the hot paths use
    (tests/test_write_engine.py cross-checks them on random stats) — an
    XLA:CPU argsort hauled through every vmapped write step is measurable.
    """
    hr = _hit_rates(st)
    g_max = hr.shape[0]
    # rank[g] = position in descending order
    order = jnp.argsort(-hr)
    rank = jnp.zeros(g_max, jnp.int32).at[order].set(jnp.arange(g_max))
    n_active = st.grp_active.sum()

    def neighbor(g, delta):
        r = rank[g] + delta
        r = jnp.clip(r, 0, n_active - 1)
        return order[r]

    return neighbor


def _neighbor_hotter(hr, active, g):
    """``order[clip(rank[g]-1, 0, n_active-1)]`` of the stable (-hr, idx)
    sort, as two reductions: the adjacent hotter group is the candidate
    (hotter than g, or same hr with lower index) with the LOWEST hit rate,
    ties to the highest index; with no candidate g is already hottest and
    stays put."""
    g_max = hr.shape[0]
    idx = jnp.arange(g_max, dtype=jnp.int32)
    g = jnp.asarray(g, jnp.int32)
    hr_g = hr[g]
    cand = active & ((hr > hr_g) | ((hr == hr_g) & (idx < g)))
    min_hr = jnp.min(jnp.where(cand, hr, jnp.inf))
    nb = jnp.max(jnp.where(cand & (hr == min_hr), idx, -1))
    return jnp.where(jnp.any(cand), nb, g).astype(jnp.int32)


def _neighbor_colder(hr, active, g, *, g_known_active: bool = False):
    """``order[clip(rank[g]+1, 0, n_active-1)]``: the candidate set is
    every active group strictly after g in (-hr, index) lexicographic
    order, and the neighbor is its (max hr, then min index) element. An
    empty candidate set means an active g is already the coldest and stays
    put; an inactive g (post-merge corner) falls to the coldest active —
    exactly argsort's clip(rank+1, n_active-1).

    g_known_active: trace-time promise that g is active (GC always drains
    an active group), which drops the coldest-active fallback reductions —
    this runs once per unrolled iteration of the drain's demotion scan.
    """
    g_max = hr.shape[0]
    idx = jnp.arange(g_max, dtype=jnp.int32)
    g = jnp.asarray(g, jnp.int32)
    hr_g = hr[g]
    cand = active & ((hr < hr_g) | ((hr == hr_g) & (idx > g)))
    best_hr = jnp.max(jnp.where(cand, hr, -2.0))
    nb = jnp.min(jnp.where(cand & (hr == best_hr), idx, g_max))
    if g_known_active:
        fallback = g
    else:
        cold_hr = jnp.min(jnp.where(active, hr, jnp.inf))
        coldest = jnp.max(jnp.where(active & (hr == cold_hr), idx, -1))
        fallback = jnp.where(active[g], g, coldest)
    return jnp.where(jnp.any(cand), nb, fallback).astype(jnp.int32)


def _target_group_app(ctx: SimContext, st: SimState, lba, cur_g, policy, rate_fn):
    """Target group for an application update of `lba` living in cur_g.

    The detector is dispatched at TRACE time from ``ctx.mcfg.td_mode``:
    every compiled step (one drive under jit, or one structure-homogeneous
    fleet sub-batch under vmap — see fleet._part_key) has exactly one
    detector, so the former per-step ``lax.switch`` over all branches —
    which under vmap selected the full [G, bits] bloom triple three ways
    every write — is structurally a single branch.
    """
    cur_g = jnp.asarray(cur_g, jnp.int32)
    td = ctx.mcfg.td_mode
    if td == "static" or not ctx.can_demote:
        # pages never change temperature: no detector machinery at all
        return st, cur_g
    if td == "fdp":
        # fixed assumed per-page rate bands: promote if ≥2× the group's
        # assumed rate (paper §5/§6: FDP's fixed-order assumption)
        r = rate_fn(st, lba)
        promote = r > 2.0 * policy["fdp_rate"][cur_g]
        nb = _neighbor_hotter(_hit_rates(st), st.grp_active, cur_g)
        return st, jnp.where(promote, nb, cur_g).astype(jnp.int32)
    assert td == "bloom", td
    # bloom (§5.6): in both filters → promote
    st, in_both = _bloom_update(ctx, st, lba, cur_g)
    nb = _neighbor_hotter(_hit_rates(st), st.grp_active, cur_g)
    return st, jnp.where(in_both, nb, cur_g).astype(jnp.int32)


def _target_group_gc(ctx: SimContext, st: SimState, lba, cur_g, policy, rate_fn):
    """Per-page GC demotion target (the reference drain's demote_fn);
    trace-time detector dispatch, like :func:`_target_group_app`."""
    cur_g = jnp.asarray(cur_g, jnp.int32)
    td = ctx.mcfg.td_mode
    if td == "static" or not ctx.can_demote:
        return cur_g
    if td == "fdp":
        r = rate_fn(st, lba)
        demote = r < 0.5 * policy["fdp_rate"][cur_g]
        nb = _neighbor_colder(_hit_rates(st), st.grp_active, cur_g)
        return jnp.where(demote, nb, cur_g).astype(jnp.int32)
    assert td == "bloom", td
    # bloom: in neither filter during a migration → demote
    in_active = _bloom_query(ctx, st.bloom_active, lba, cur_g)
    in_passive = _bloom_query(ctx, st.bloom_passive, lba, cur_g)
    nb = _neighbor_colder(_hit_rates(st), st.grp_active, cur_g)
    return jnp.where(
        ~in_active & ~in_passive, nb, cur_g
    ).astype(jnp.int32)


# -- bloom filter pair (per group) ------------------------------------------

def _bloom_hashes(ctx: SimContext, lba):
    bits = bloom_bits(ctx.geom, ctx.mcfg)
    u = lba.astype(jnp.uint32)
    h1 = (u * jnp.uint32(2654435761)) % jnp.uint32(bits)
    h2 = (u * jnp.uint32(40503) + jnp.uint32(99991)) % jnp.uint32(bits)
    return h1.astype(jnp.int32), h2.astype(jnp.int32), bits


def _bloom_query(ctx, filt, lba, g):
    h1, h2, _ = _bloom_hashes(ctx, lba)
    return filt[g, h1] & filt[g, h2]


def _bloom_update(ctx: SimContext, st: SimState, lba, g):
    """Insert lba into group g's active filter; rotate when the group's
    write interval (= group size) elapses. Returns (st, was_in_both)."""
    h1, h2, _ = _bloom_hashes(ctx, lba)
    in_active = st.bloom_active[g, h1] & st.bloom_active[g, h2]
    in_passive = st.bloom_passive[g, h1] & st.bloom_passive[g, h2]
    bloom_active = st.bloom_active.at[g, h1].set(True).at[g, h2].set(True)
    bloom_writes = st.bloom_writes.at[g].add(1)
    rotate = bloom_writes[g] >= jnp.maximum(
        st.grp_size[g], ctx.mcfg.bloom_rotate_min_writes
    )
    # row-masked rotation (no lax.cond: under vmap a cond would select over
    # the full [G, bits] filter pair every step; this touches one row)
    row_active = bloom_active[g]
    st = st.replace(
        bloom_passive=st.bloom_passive.at[g].set(
            jnp.where(rotate, row_active, st.bloom_passive[g])
        ),
        bloom_active=bloom_active.at[g].set(
            jnp.where(rotate, False, row_active)
        ),
        bloom_writes=bloom_writes.at[g].set(
            jnp.where(rotate, 0, bloom_writes[g])
        ),
    )
    return st, in_active & in_passive


# ---------------------------------------------------------------------------
# the step + runner
# ---------------------------------------------------------------------------

def _step_tail(ctx: SimContext, st: SimState, lba, t, g, policy, lookup):
    """GC → emergency valve → write → movement ops → §5.1 interval update.

    The seed step order downstream of invalidate + target selection. Runs
    on every write of the reference engine (``ctx.fast_path=False``) and as
    the heavy branch of the split engine. All pool/budget predicates are
    O(1) reads of the carried ``free_blocks``/``grp_surplus`` accounting.
    """
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block

    # GC when the group needs a new block it is not entitled to, or the
    # pool is at reserve.
    blk = st.active_blk[g]
    needs_block = jnp.where(
        blk >= 0, st.fill[jnp.maximum(blk, 0)] >= b, True
    )
    over_budget = st.grp_phys[g] >= st.grp_alloc[g]
    low_pool = st.free_blocks <= mcfg.gc_reserve_blocks
    do_gc = needs_block & (over_budget | low_pool)
    st = _gc_one(ctx, st, g, policy, lookup, policy["gc_w"], enabled=do_gc)

    # emergency valve: if the pool is (nearly) empty, greedily reclaim
    # from the fullest group until headroom returns (bounded loop; only
    # fires when a policy briefly overdraws its budget). The carry is the
    # GC-mutable field subset, not the whole state.
    def needs_air(s, tries):
        return (s.free_blocks < 2) & (tries < mcfg.valve_max_tries)

    def reclaim(s, tries):
        # global greedy: the best victim anywhere (its group pays)
        closed = s.state == CLOSED
        score = jnp.where(closed, s.live, INT_MAX)
        victim = jnp.argmin(score)
        g_v = jnp.maximum(s.group_of[victim], 0)
        return (
            _gc_one(ctx, s, g_v, policy, lookup,
                    jnp.asarray(GC_W_GREEDY, jnp.float32)),
            tries + 1,
        )

    st, _ = _while_fields(needs_air, reclaim, st, 0, _gc_fields(ctx))

    st = _write_page(ctx, st, lba, g, is_migration=False)
    st = st.replace(
        n_app=st.n_app + 1,
        grp_writes=st.grp_writes.at[g].add(1),
    )

    # movement operations (§5.3): one compaction GC per step on the most
    # surplus group, donating the redeemed block to the pool. Structurally
    # absent when the context rules movement out (ctx.use_movement=False).
    if ctx.use_movement:
        g_s = jnp.argmax(st.grp_surplus)
        pool_ok = st.free_blocks >= 2  # migration headroom
        st = _gc_one(
            ctx, st, g_s, policy, lookup, policy["gc_w"],
            enabled=policy["movement_ops"] & (st.grp_surplus[g_s] >= 1)
            & pool_ok,
        )

    # interval completion (§5.1); t+1 == n_app after this write, so the
    # predicate is exactly (n_app % h == 0). With a fleet-shared h it is
    # a SCALAR shared by every vmapped drive; per-drive interval sweeps
    # (ctx.per_drive_interval) read the traced policy["h"] instead. In
    # op-stream mode t is the EVENT index (trims interleave, so write
    # counts diverge across drives) and the predicate reads the carried
    # write clock — same value under a pure-write stream, where
    # st.n_app == t + 1 at this point.
    h = policy["h"] if ctx.per_drive_interval else ctx.h
    if ctx.with_trim:
        is_interval = (st.n_app % h) == 0
    else:
        is_interval = ((t + 1) % h) == 0
    interval_fields = _INTERVAL_FIELDS
    if ctx.with_faults:
        # §5.2 merges relabel retired counts (see _maybe_create_or_merge)
        interval_fields = interval_fields + ("grp_retired",)
    st = _cond_fields(
        is_interval,
        lambda s: _interval_update(ctx, s, policy),
        st,
        interval_fields,
    )
    return st


def _trim_page(ctx: SimContext, st: SimState, lba):
    """The op-stream TRIM step: unmap ``lba`` and kill its physical slot.

    The fast-path peer of the ``kernels/write_path`` append — the counter
    half rides :func:`_invalidate_counts` (O(1) carried updates: ``live``,
    ``grp_size``/``grp_live``, ``mapped_pages``) and the mapping half is
    one fused ``apply_trim`` op. A TRIM frees space, so it can never need
    the GC / valve / movement machinery, and it completes no application
    write, so it never closes a §5.1 interval: there is no heavy path.
    A re-trim of an already-unmapped page is a counted no-op.
    """
    st, _old_g, old_pm = _invalidate_counts(ctx, st, lba)
    page_map, valid = apply_trim(st.page_map, st.valid, lba, old_pm)
    # the killed slot is a trimmed-but-unerased hole: tally it on its
    # block for the victim score's τ term (cleared when the block erases)
    has = old_pm >= 0
    blk_c = jnp.maximum(old_pm, 0) // ctx.geom.pages_per_block
    return st.replace(
        page_map=page_map, valid=valid, n_trim=st.n_trim + 1,
        trim_dead=st.trim_dead.at[blk_c].add(jnp.where(has, 1, 0)),
    )


def _halt_wrap(ctx: SimContext, body):
    """Freeze a degraded drive: once ``drive_status`` leaves STATUS_OK
    (spares exhausted, see :func:`_erase_fault_retire`) every subsequent
    op is a counted no-op — the drive is an inert lane that only bumps
    ``n_halted``, never a crashed trace or an invariant violation. The
    guard is one dieted cond over the op-mutable field set; fault-free
    contexts return ``body`` unchanged (zero structural footprint).
    Under vmap a degraded lane still executes both select branches on its
    (frozen, valid) state — all inner loops stay bounded."""
    if not ctx.with_faults:
        return body

    def guarded(st, *args):
        out = jax.lax.cond(
            st.drive_status == STATUS_OK,
            lambda s: _fields_of(body(s, *args), _OP_FIELDS),
            lambda s: _fields_of(
                s.replace(n_halted=s.n_halted + 1), _OP_FIELDS
            ),
            st,
        )
        return st.replace(**dict(zip(_OP_FIELDS, out)))

    return guarded


def make_step(ctx: SimContext, policy, rate_fn, page_group0=None):
    """Build the per-event scan step.

    policy: traced pytree from :func:`policy_from_config` (per-drive under
    vmap). rate_fn(st, lba, t) -> true per-page update rate of `lba` at
    scan index t (oracle detector input; phase-aware in fleets).

    Pure-write mode (``ctx.with_trim=False``, the default): scan input =
    (lba, t); t is the global application-write index, which is
    deliberately NOT taken from batched state so the interval predicate
    stays a scalar under vmap whenever every drive shares h
    (ctx.per_drive_interval=False) — the expensive §5.1 bookkeeping then
    lowers to a real branch taken every h steps, not a per-step select.

    Op-stream mode (``ctx.with_trim=True``): scan input = (op, lba, t)
    with ``op ∈ {OP_WRITE, OP_TRIM}`` and t the EVENT index (it feeds only
    the oracle's phase lookup). A WRITE event runs the same write body as
    pure-write mode — only the §5.1 predicate reads the carried ``n_app``
    instead of t, the identical value whenever every event is a write —
    and a TRIM event runs :func:`_trim_page`. ``page_group0`` ([LBA]
    int32, the workload's layout groups) resolves the residence group of
    a write that RE-MAPS a trimmed page, which has no physical home to
    inherit a group from.

    With ``ctx.fast_path=True`` (default) the write is split: one whose
    target group has an open active block with room, with the pool above
    reserve, no redeemable movement surplus anywhere, and no interval
    boundary, takes the LEAN branch — invalidate counters, pick the group,
    and one fused append (``kernels/write_path``). Everything else
    (:func:`_step_tail`) runs only when one of those O(1) scalar predicates
    trips. The predicates are exact, not conservative: a fast write is
    bit-identical to what the heavy path would have produced, which
    tests/test_write_engine.py asserts against ``fast_path=False``.
    """
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block
    if ctx.with_trim:
        assert page_group0 is not None, "op-stream step needs page_group0"
        page_group0 = jnp.asarray(page_group0, jnp.int32)

    def resolve_group(st, old_g, had_mapping, lba):
        # a write that re-maps a trimmed page inherits the workload's
        # layout group (first active group if dynamic-mode merging has
        # retired that slot); mapped pages keep their residence group
        pg0 = page_group0[lba]
        pg0 = jnp.where(
            st.grp_active[pg0], pg0, jnp.argmax(st.grp_active)
        ).astype(jnp.int32)
        return jnp.where(had_mapping, old_g, pg0).astype(jnp.int32)

    def reference_write(st, lba, t, lookup):
        # the seed-shaped single-path write, shared by both stream modes
        if ctx.with_trim:
            had = st.page_map[lba] >= 0
            st, old_g = _invalidate(ctx, st, lba)
            old_g = resolve_group(st, old_g, had, lba)
        else:
            st, old_g = _invalidate(ctx, st, lba)
        st, g = _target_group_app(ctx, st, lba, old_g, policy, lookup)
        g = jnp.where(st.grp_active[g], g, old_g)
        return _step_tail(ctx, st, lba, t, g, policy, lookup)

    def split_write(st, lba, t, lookup):
        st, old_g, old_pm = _invalidate_counts(ctx, st, lba)
        if ctx.with_trim:
            old_g = resolve_group(st, old_g, old_pm >= 0, lba)
        st, g = _target_group_app(ctx, st, lba, old_g, policy, lookup)
        g = jnp.where(st.grp_active[g], g, old_g)

        # O(1) heavy-path predicates. Exactness argument per term:
        #  * room in the active block → _step_tail's do_gc and the
        #    _write_page alloc are both predicated on the block being full
        #    (low_pool alone never GCs without needs_block);
        #  * free_blocks ≥ 2 → the emergency valve cannot fire, and the
        #    fast write claims no block so the pool is untouched;
        #  * movement: a fast write changes no grp_phys/grp_alloc, so the
        #    post-write surplus the tail would read equals the carried
        #    pre-write surplus — if its max is < 1, movement cannot fire;
        #  * the interval predicate is the tail's own (op-stream mode
        #    reads the carried write clock, not the event index).
        blk = st.active_blk[g]
        blk_c = jnp.maximum(blk, 0)
        has_room = (blk >= 0) & (st.fill[blk_c] < b)
        valve_may = st.free_blocks < 2
        if ctx.use_movement:
            movement_may = policy["movement_ops"] & (
                jnp.max(st.grp_surplus) >= 1
            )
        else:
            movement_may = False
        h = policy["h"] if ctx.per_drive_interval else ctx.h
        if ctx.with_trim:
            is_interval = ((st.n_app + 1) % h) == 0
        else:
            is_interval = ((t + 1) % h) == 0
        heavy = (~has_room) | valve_may | movement_may | is_interval

        def heavy_path(st):
            st = _clear_valid(ctx, st, old_pm)
            return _step_tail(ctx, st, lba, t, g, policy, lookup)

        def fast_path(st):
            slot = st.fill[blk_c]
            page_map, slot_lba, valid = apply_write(
                st.page_map, st.slot_lba, st.valid, lba, old_pm, blk_c, slot
            )
            return st.replace(
                page_map=page_map,
                slot_lba=slot_lba,
                valid=valid,
                fill=st.fill.at[blk_c].add(1),
                live=st.live.at[blk_c].add(1),
                grp_size=st.grp_size.at[g].add(1),
                grp_live=st.grp_live.at[g].add(1),
                mapped_pages=st.mapped_pages + 1,
                n_app=st.n_app + 1,
                grp_writes=st.grp_writes.at[g].add(1),
            )

        out = jax.lax.cond(
            heavy,
            lambda s: _fields_of(heavy_path(s), _STEP_FIELDS),
            lambda s: _fields_of(fast_path(s), _STEP_FIELDS),
            st,
        )
        return st.replace(**dict(zip(_STEP_FIELDS, out)))

    # degraded drives (faults only) freeze before any per-op work runs
    reference_write_g = _halt_wrap(ctx, reference_write)
    split_write_g = _halt_wrap(ctx, split_write)

    def reference_step(st, xs):
        lba, t = xs

        def lookup(s, l):
            return rate_fn(s, l, t)

        st = reference_write_g(st, lba, t, lookup)
        return st, (st.n_app, st.n_mig)

    def split_step(st, xs):
        lba, t = xs

        def lookup(s, l):
            return rate_fn(s, l, t)

        st = split_write_g(st, lba, t, lookup)
        return st, (st.n_app, st.n_mig)

    def op_step(st, xs):
        op, lba, t = xs

        def lookup(s, l):
            return rate_fn(s, l, t)

        write_fn = split_write if ctx.fast_path else reference_write

        def op_body(st):
            out = jax.lax.cond(
                op == OP_TRIM,
                lambda s: _fields_of(_trim_page(ctx, s, lba), _OP_FIELDS),
                lambda s: _fields_of(
                    write_fn(s, lba, t, lookup), _OP_FIELDS
                ),
                st,
            )
            return st.replace(**dict(zip(_OP_FIELDS, out)))

        st = _halt_wrap(ctx, op_body)(st)
        return st, (st.n_app, st.n_mig)

    if ctx.with_trim:
        return op_step
    return split_step if ctx.fast_path else reference_step


def scan_writes(ctx: SimContext, step, st: SimState, lbas, ts, ops=None):
    """Scan ``step`` over an event segment, honoring the chunking knobs.

    ``ops`` (required iff ``ctx.with_trim``): the per-event op codes; the
    scan then folds (op, lba, t) triples instead of (lba, t) pairs.

    ``ctx.trace_every == 1``: one scan over T steps, dense cumulative
    (n_app, n_mig) trace [T]. ``trace_every = E > 1``: the events are
    regrouped [T//E, E] (E must divide T) and the counters are emitted once
    per chunk — element j equals the dense trace at step (j+1)·E - 1. The
    inner chunk emits nothing, so XLA sees E fused write-steps between
    trace stores. Chunking preserves event-order semantics trivially: the
    same step function is folded over the same event sequence, only the
    loop nest and the trace sampling change. ``ctx.unroll`` unrolls the
    (inner) scan body to amortize XLA:CPU per-iteration overhead.
    """
    assert (ops is not None) == ctx.with_trim, (
        "ops stream and ctx.with_trim must agree"
    )
    t_total = int(lbas.shape[0])
    e = ctx.trace_every
    cols = (lbas, ts) if ops is None else (ops, lbas, ts)
    if e <= 1:
        return jax.lax.scan(
            step, st, cols, unroll=min(ctx.unroll, max(t_total, 1))
        )
    assert t_total % e == 0, (
        f"trace_every={e} must divide the segment length {t_total}"
    )

    def inner(s, xs):
        s, _ = step(s, xs)
        return s, None

    def chunk(s, xs):
        s, _ = jax.lax.scan(inner, s, xs, unroll=min(ctx.unroll, e))
        return s, (s.n_app, s.n_mig)

    xs = tuple(c.reshape(t_total // e, e) for c in cols)
    return jax.lax.scan(chunk, st, xs)


# st is DONATED: the scan's carry rewrites every state array, so aliasing
# the input buffers halves peak state memory (the fleet executor makes the
# same promise per shard — core/fleet_exec.py). Callers must treat the st
# they pass in as consumed (managers.simulate threads the returned state
# forward and never re-reads its input, the donation-safe signature every
# entry point follows). Backends without input-output aliasing silently
# skip donation; numerics are unaffected either way.
@functools.partial(jax.jit, static_argnames=("ctx",), donate_argnums=(1,))
def _run_jit(ctx: SimContext, st: SimState, lbas, page_rate, policy):
    def rate_fn(s, lba, t):
        return page_rate[lba]

    step = make_step(ctx, policy, rate_fn)
    ts = st.n_app + jnp.arange(lbas.shape[0], dtype=jnp.int32)
    return scan_writes(ctx, step, st, lbas, ts)


@functools.partial(jax.jit, static_argnames=("ctx",), donate_argnums=(1,))
def _run_ops_jit(ctx: SimContext, st: SimState, ops, lbas, page_rate,
                 page_group0, policy):
    def rate_fn(s, lba, t):
        return page_rate[lba]

    step = make_step(ctx, policy, rate_fn, page_group0)
    ts = jnp.arange(lbas.shape[0], dtype=jnp.int32)  # event index
    return scan_writes(ctx, step, st, lbas, ts, ops)


def run(ctx: SimContext, st: SimState, lbas, *, ops=None, page_group0=None,
        page_rate=None, assumed_p=None, fdp_rate=None):
    """Run the simulator over a segment of writes (or, with ``ops``, of
    WRITE/TRIM events).

    lbas: int32 [T]; page_rate: float32 [LBA] true per-page update rates
    (oracle detector modes). ops: int32 [T] op codes (requires
    ``ctx.with_trim=True`` and ``page_group0`` — the [LBA] layout groups
    re-mapped pages land in). Returns (final_state, trace dict of
    CUMULATIVE counters — [T] dense, or [T // ctx.trace_every] sampled at
    every trace_every-th event) — segment the workload (e.g. at a
    frequency swap) by calling run() repeatedly with updated oracle
    arrays. ``st`` is donated into the jitted scan: treat the passed-in
    state as consumed and read only the returned one (thread it forward
    across segments, as managers.simulate does).
    """
    lbas = jnp.asarray(lbas, jnp.int32)
    if page_rate is None:
        page_rate = jnp.zeros(ctx.geom.lba_pages, jnp.float32)
    policy = policy_from_config(ctx, assumed_p, fdp_rate)
    assert (ops is not None) == ctx.with_trim, (
        "pass ops= iff the context is op-stream (ctx.with_trim)"
    )
    if ops is None:
        st, (app, mig) = _run_jit(
            ctx, st, lbas, jnp.asarray(page_rate, jnp.float32), policy
        )
    else:
        assert page_group0 is not None
        st, (app, mig) = _run_ops_jit(
            ctx, st, jnp.asarray(ops, jnp.int32), lbas,
            jnp.asarray(page_rate, jnp.float32),
            jnp.asarray(page_group0, jnp.int32), policy,
        )
    return st, {"app": app, "mig": mig}
