"""Write-granularity SSD simulator (jittable, lax.scan over writes).

One scan step = one application write:
  1. invalidate the page's old physical slot,
  2. pick the target group (temperature detection, §5.6 / oracle),
  3. garbage-collect inside the group if it's out of budgeted space (§5.4),
  4. append the page to the group's active block,
  5. every h writes: interval bookkeeping (§5.1) — EWMA update frequencies,
     re-allocate over-provisioning (§5.5), create/merge groups (§5.2),
  6. movement operations (§5.3): ≤1 proactive compaction GC per step on the
     most block-surplus group, donating redeemed blocks to the pool.

GC migrations re-enter the same write path (so migrated pages can be demoted
by the detector, as in Listing 1/3 of the paper).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.allocation import (
    allocate_by_frequency,
    allocate_by_size,
    allocate_closed_form,
)
from repro.core.ssd import CLOSED, FREE, OPEN, Geometry, ManagerConfig

INT_MAX = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class SimContext:
    """Static context threaded through the jitted step."""

    geom: Geometry
    mcfg: ManagerConfig
    n_groups: int  # initial groups (may grow in dynamic mode)

    @property
    def h(self) -> int:
        return max(16, int(self.geom.lba_pages * self.mcfg.interval_frac))

    @property
    def f_min_pages(self) -> int:
        return self.geom.n_luns * self.geom.pages_per_block


# ---------------------------------------------------------------------------
# primitive state updates
# ---------------------------------------------------------------------------

def _pop_free_block(st, g):
    """Claim a FREE block for group g (becomes its OPEN active block)."""
    free_mask = st["state"] == FREE
    blk = jnp.argmax(free_mask)  # reserve logic upstream guarantees ≥1
    ok = free_mask[blk]
    st = dict(st)
    st["state"] = st["state"].at[blk].set(jnp.where(ok, OPEN, st["state"][blk]))
    st["group_of"] = st["group_of"].at[blk].set(
        jnp.where(ok, g, st["group_of"][blk])
    )
    st["fill"] = st["fill"].at[blk].set(jnp.where(ok, 0, st["fill"][blk]))
    st["grp_phys"] = st["grp_phys"].at[g].add(jnp.where(ok, 1, 0))
    # LRU clock: a block's age is its claim time — "least recently erased"
    # degenerates into cleaning freshly-filled (never-erased) blocks if ages
    # only advance on erase.
    st["stamp"] = st["stamp"].at[blk].set(jnp.where(ok, st["clock"], st["stamp"][blk]))
    st["clock"] = st["clock"] + jnp.where(ok, 1, 0)
    return st, blk, ok


def _write_page(ctx: SimContext, st, lba, g, *, is_migration: bool):
    """Append page `lba` to group g's active block (allocating if needed)."""
    b = ctx.geom.pages_per_block  # noqa: shadows module-level nothing
    blk = st["active_blk"][g]
    blk_full = jnp.where(blk >= 0, st["fill"][jnp.maximum(blk, 0)] >= b, True)

    def alloc(st):
        st = dict(st)
        old = st["active_blk"][g]
        # seal the previous active block
        st["state"] = st["state"].at[jnp.maximum(old, 0)].set(
            jnp.where(old >= 0, CLOSED, st["state"][jnp.maximum(old, 0)])
        )
        st, new_blk, ok = _pop_free_block(st, g)
        st["active_blk"] = st["active_blk"].at[g].set(
            jnp.where(ok, new_blk, old)
        )
        return st

    st = jax.lax.cond(blk_full, alloc, lambda s: dict(s), st)
    blk = st["active_blk"][g]
    slot = st["fill"][blk]
    # overflow guard: if the pool was empty the active block may still be
    # full — drop the write and count it (tests assert this never fires).
    ok = (blk >= 0) & (slot < b)
    blk_c = jnp.maximum(blk, 0)
    slot_c = jnp.minimum(slot, b - 1)
    st = dict(st)
    st["fill"] = st["fill"].at[blk_c].add(jnp.where(ok, 1, 0))
    st["slot_lba"] = st["slot_lba"].at[blk_c, slot_c].set(
        jnp.where(ok, lba, st["slot_lba"][blk_c, slot_c])
    )
    st["valid"] = st["valid"].at[blk_c, slot_c].set(
        jnp.where(ok, True, st["valid"][blk_c, slot_c])
    )
    st["live"] = st["live"].at[blk_c].add(jnp.where(ok, 1, 0))
    st["map_blk"] = st["map_blk"].at[lba].set(jnp.where(ok, blk, -1))
    st["map_slot"] = st["map_slot"].at[lba].set(jnp.where(ok, slot, -1))
    st["grp_size"] = st["grp_size"].at[g].add(jnp.where(ok, 1, 0))
    st["n_dropped"] = st["n_dropped"] + jnp.where(ok, 0, 1)
    if is_migration:
        st["n_mig"] = st["n_mig"] + jnp.where(ok, 1, 0)
    return st


def _invalidate(st, lba):
    blk = st["map_blk"][lba]
    slot = st["map_slot"][lba]
    has = blk >= 0
    blk_c = jnp.maximum(blk, 0)
    old_g = st["group_of"][blk_c]
    st = dict(st)
    st["valid"] = st["valid"].at[blk_c, slot].set(
        jnp.where(has, False, st["valid"][blk_c, slot])
    )
    st["live"] = st["live"].at[blk_c].add(jnp.where(has, -1, 0))
    st["grp_size"] = st["grp_size"].at[jnp.maximum(old_g, 0)].add(
        jnp.where(has & (old_g >= 0), -1, 0)
    )
    return st, jnp.where(has, old_g, 0)


# ---------------------------------------------------------------------------
# garbage collection (one victim) — §5.4
# ---------------------------------------------------------------------------

def _select_victim(ctx: SimContext, st, g):
    closed = (st["state"] == CLOSED) & (st["group_of"] == g)
    if ctx.mcfg.gc_policy == "lru":
        score = jnp.where(closed, st["stamp"], INT_MAX)
    else:  # greedy
        score = jnp.where(closed, st["live"], INT_MAX)
    victim = jnp.argmin(score)
    ok = closed[victim]
    if ctx.mcfg.gc_policy == "greedy":
        # a fully-live victim frees nothing: skip (movement-op no-op guard)
        ok = ok & (st["live"][victim] < ctx.geom.pages_per_block)
    return victim, ok


def _gc_one(ctx: SimContext, st, g, demote_fn):
    """GC one victim in group g; migrate live pages via the write path.

    demote_fn(st, lba, g) -> target group for a migrated page (§5.6 demotion:
    bloom/fdp detectors may demote during GC; static keeps g).
    """
    victim, ok = _select_victim(ctx, st, g)
    # migrations may need one fresh block beyond the active's free slots:
    # never start a GC with an empty pool (callers keep it ≥ 2).
    ok = ok & (jnp.sum(st["state"] == FREE) >= 1)

    def do(st):
        b = ctx.geom.pages_per_block

        def body(j, st):
            lba = st["slot_lba"][victim, j]
            is_live = st["valid"][victim, j]

            def mig(st):
                st = dict(st)
                st["valid"] = st["valid"].at[victim, j].set(False)
                st["live"] = st["live"].at[victim].add(-1)
                g_tgt = demote_fn(st, lba, g)
                st["grp_size"] = st["grp_size"].at[g].add(-1)
                return _write_page(ctx, st, lba, g_tgt, is_migration=True)

            return jax.lax.cond(is_live, mig, lambda s: dict(s), st)

        st = jax.lax.fori_loop(0, b, body, dict(st))
        # erase
        st["state"] = st["state"].at[victim].set(FREE)
        st["group_of"] = st["group_of"].at[victim].set(-1)
        st["fill"] = st["fill"].at[victim].set(0)
        st["live"] = st["live"].at[victim].set(0)
        st["slot_lba"] = st["slot_lba"].at[victim].set(-1)
        st["valid"] = st["valid"].at[victim].set(False)
        st["stamp"] = st["stamp"].at[victim].set(st["clock"])
        st["clock"] = st["clock"] + 1
        st["grp_phys"] = st["grp_phys"].at[g].add(-1)
        st["n_erase"] = st["n_erase"] + 1
        return st

    return jax.lax.cond(ok, do, lambda s: dict(s), st)


# ---------------------------------------------------------------------------
# over-provisioning allocation (interval) — §5.5
# ---------------------------------------------------------------------------

def _recompute_alloc(ctx: SimContext, st, assumed_p=None):
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block
    active = st["grp_active"]
    s = jnp.where(active, st["grp_size"].astype(jnp.float32), 0.0)
    s = jnp.maximum(s, jnp.where(active, 1.0, 0.0))
    if mcfg.alloc_mode == "fdp_assumed":
        p = jnp.where(active, assumed_p, 0.0)
    else:
        p = jnp.where(active, st["grp_p"], 0.0)
    p = p / jnp.maximum(p.sum(), 1e-9)
    # usable OP = spare pages beyond logical content, minus the GC reserve
    # and one block per active group (absorbs the per-group ceil slack so
    # the budgets can never collectively over-claim the pool)
    n_active = active.sum()
    op_total = (
        jnp.asarray(geom.pba_pages, jnp.float32)
        - (mcfg.gc_reserve_blocks + 1 + n_active) * b
        - s.sum()
    )

    if mcfg.alloc_mode in ("wolf", "fdp_assumed", "optimal"):
        op = allocate_closed_form(
            s, p, op_total,
            cold_rule=True,
            cold_hit_rate_frac=mcfg.cold_hit_rate_frac,
            cold_op_frac=mcfg.cold_op_frac,
        )
    elif mcfg.alloc_mode == "size":
        op = allocate_by_size(s, op_total)
    elif mcfg.alloc_mode == "freq":
        op = allocate_by_frequency(p, op_total)
    else:  # single group / no reallocation
        op = allocate_by_size(s, op_total)
    alloc_blocks = jnp.ceil((s + op) / b).astype(jnp.int32)
    alloc_blocks = jnp.where(active, jnp.maximum(alloc_blocks, 1), 0)
    st = dict(st)
    st["grp_alloc"] = alloc_blocks
    return st


def _interval_update(ctx: SimContext, st, assumed_p):
    mcfg = ctx.mcfg
    st = dict(st)
    u = st["grp_writes"].astype(jnp.float32) / ctx.h
    active = st["grp_active"]
    st["grp_p"] = jnp.where(
        active, st["grp_p"] * (1 - mcfg.ewma_a) + mcfg.ewma_a * u, 0.0
    )
    st["grp_writes"] = jnp.zeros_like(st["grp_writes"])
    st["interval"] = st["interval"] + 1
    st["cooldown"] = jnp.maximum(st["cooldown"] - 1, 0)
    if mcfg.dynamic_groups:
        st = _maybe_create_or_merge(ctx, st)
    st = _recompute_alloc(ctx, st, assumed_p)
    return st


# ---------------------------------------------------------------------------
# group creation / merging (dynamic mode) — §5.2
# ---------------------------------------------------------------------------

def _hit_rates(st):
    s = jnp.maximum(st["grp_size"].astype(jnp.float32), 1.0)
    hr = st["grp_p"] / s
    return jnp.where(st["grp_active"], hr, -1.0)


def _maybe_create_or_merge(ctx: SimContext, st):
    mcfg = ctx.mcfg
    hr = _hit_rates(st)
    order = jnp.argsort(-hr)  # hottest first
    hottest, second = order[0], order[1]
    n_active = st["grp_active"].sum()
    can_slot = n_active < mcfg.max_groups
    hot_ratio = hr[hottest] / jnp.maximum(hr[second], 1e-12)
    create = (
        can_slot
        & (st["cooldown"] == 0)
        & (n_active >= 2)
        & (hot_ratio >= mcfg.q_create)
        & (st["grp_size"][hottest] >= ctx.f_min_pages)
    )

    def do_create(st):
        st = dict(st)
        slot = jnp.argmin(st["grp_active"])  # first inactive slot
        st["grp_active"] = st["grp_active"].at[slot].set(True)
        # seed stats: half the hottest group's measured frequency
        st["grp_p"] = st["grp_p"].at[slot].set(st["grp_p"][hottest] * 0.5)
        st["grp_size"] = st["grp_size"].at[slot].set(0)
        st["grp_phys"] = st["grp_phys"].at[slot].set(0)
        st["grp_created"] = st["grp_created"].at[slot].set(st["interval"])
        st["cooldown"] = jnp.asarray(mcfg.w_intervals, jnp.int32)
        return st

    st = jax.lax.cond(create, do_create, lambda s: dict(s), st)

    # merge: coldest adjacent pair that converged, or an undersized group
    hr = _hit_rates(st)
    order = jnp.argsort(-hr)
    n_active = st["grp_active"].sum()
    # adjacent pair ratios in hit-rate order
    hr_sorted = hr[order]
    idx = jnp.arange(hr.shape[0])
    valid_pair = (idx + 1 < n_active)
    ratio = hr_sorted / jnp.maximum(jnp.roll(hr_sorted, -1), 1e-12)
    converged = valid_pair & (ratio < 1.3) & (hr_sorted > 0)
    tiny = valid_pair & (
        st["grp_size"][order] < jnp.asarray(ctx.f_min_pages, jnp.int32)
    ) & (jnp.roll(hr_sorted, -1) > 0)
    mergeable = converged | tiny
    pair_i = jnp.argmax(mergeable)
    do_merge = (
        mergeable[pair_i] & (st["cooldown"] == 0) & (n_active > 2)
    )

    def merge(st):
        st = dict(st)
        g_from = order[pair_i]          # hotter of the pair
        g_to = order[pair_i + 1]        # absorbed into the colder
        # relabel blocks (the paper: a merge is logical)
        st["group_of"] = jnp.where(
            st["group_of"] == g_from, g_to, st["group_of"]
        )
        # seal g_from's active block (no longer reachable)
        ab = st["active_blk"][g_from]
        st["state"] = st["state"].at[jnp.maximum(ab, 0)].set(
            jnp.where(ab >= 0, CLOSED, st["state"][jnp.maximum(ab, 0)])
        )
        st["active_blk"] = st["active_blk"].at[g_from].set(-1)
        st["grp_size"] = st["grp_size"].at[g_to].add(st["grp_size"][g_from])
        st["grp_phys"] = st["grp_phys"].at[g_to].add(st["grp_phys"][g_from])
        st["grp_p"] = st["grp_p"].at[g_to].add(st["grp_p"][g_from])
        st["grp_writes"] = st["grp_writes"].at[g_to].add(st["grp_writes"][g_from])
        for key in ("grp_size", "grp_phys", "grp_p", "grp_writes"):
            st[key] = st[key].at[g_from].set(0)
        st["grp_active"] = st["grp_active"].at[g_from].set(False)
        st["cooldown"] = jnp.asarray(mcfg.w_intervals, jnp.int32)
        return st

    return jax.lax.cond(do_merge, merge, lambda s: dict(s), st)


# ---------------------------------------------------------------------------
# temperature detection — §5.6 (+ oracle modes for §6 experiments)
# ---------------------------------------------------------------------------

def _sgv_neighbors(st):
    """hotter_of[g], colder_of[g] by current hit-rate order."""
    hr = _hit_rates(st)
    g_max = hr.shape[0]
    # rank[g] = position in descending order
    order = jnp.argsort(-hr)
    rank = jnp.zeros(g_max, jnp.int32).at[order].set(jnp.arange(g_max))
    n_active = st["grp_active"].sum()

    def neighbor(g, delta):
        r = rank[g] + delta
        r = jnp.clip(r, 0, n_active - 1)
        return order[r]

    return neighbor


def _target_group_app(ctx: SimContext, st, lba, cur_g, page_rate, bloom):
    """Target group for an application update of `lba` living in cur_g."""
    mode = ctx.mcfg.td_mode
    if mode == "static":
        return st, cur_g
    neighbor = _sgv_neighbors(st)
    if mode == "fdp":
        # fixed assumed per-page rate bands: promote if ≥2× the group's
        # assumed rate (paper §5/§6: FDP's fixed-order assumption)
        assumed = bloom["fdp_rate"]  # [G] assumed per-page rate
        r = page_rate[lba]
        promote = r > 2.0 * assumed[cur_g]
        return st, jnp.where(promote, neighbor(cur_g, -1), cur_g)
    # bloom (§5.6): in both filters → promote
    st, in_both = _bloom_update(ctx, st, lba, cur_g)
    return st, jnp.where(in_both, _sgv_neighbors(st)(cur_g, -1), cur_g)


def _target_group_gc(ctx: SimContext, st, lba, cur_g, page_rate, bloom):
    mode = ctx.mcfg.td_mode
    if mode == "static":
        return cur_g
    neighbor = _sgv_neighbors(st)
    if mode == "fdp":
        assumed = bloom["fdp_rate"]
        r = page_rate[lba]
        demote = r < 0.5 * assumed[cur_g]
        return jnp.where(demote, neighbor(cur_g, +1), cur_g)
    # bloom: in neither filter during a migration → demote
    in_active = _bloom_query(ctx, st["bloom_active"], lba, cur_g)
    in_passive = _bloom_query(ctx, st["bloom_passive"], lba, cur_g)
    return jnp.where(~in_active & ~in_passive, neighbor(cur_g, +1), cur_g)


# -- bloom filter pair (per group) ------------------------------------------

def _bloom_hashes(ctx: SimContext, lba):
    bits = ctx.geom.lba_pages * ctx.mcfg.bloom_bits_per_page // ctx.mcfg.max_groups
    bits = max(bits, 64)
    u = lba.astype(jnp.uint32)
    h1 = (u * jnp.uint32(2654435761)) % jnp.uint32(bits)
    h2 = (u * jnp.uint32(40503) + jnp.uint32(99991)) % jnp.uint32(bits)
    return h1.astype(jnp.int32), h2.astype(jnp.int32), bits


def _bloom_query(ctx, filt, lba, g):
    h1, h2, _ = _bloom_hashes(ctx, lba)
    return filt[g, h1] & filt[g, h2]


def _bloom_update(ctx: SimContext, st, lba, g):
    """Insert lba into group g's active filter; rotate when the group's
    write interval (= group size) elapses. Returns (st, was_in_both)."""
    h1, h2, _ = _bloom_hashes(ctx, lba)
    in_active = st["bloom_active"][g, h1] & st["bloom_active"][g, h2]
    in_passive = st["bloom_passive"][g, h1] & st["bloom_passive"][g, h2]
    st = dict(st)
    st["bloom_active"] = (
        st["bloom_active"].at[g, h1].set(True).at[g, h2].set(True)
    )
    st["bloom_writes"] = st["bloom_writes"].at[g].add(1)
    rotate = st["bloom_writes"][g] >= jnp.maximum(st["grp_size"][g], 64)

    def do_rotate(st):
        st = dict(st)
        st["bloom_passive"] = st["bloom_passive"].at[g].set(st["bloom_active"][g])
        st["bloom_active"] = st["bloom_active"].at[g].set(False)
        st["bloom_writes"] = st["bloom_writes"].at[g].set(0)
        return st

    st = jax.lax.cond(rotate, do_rotate, lambda s: dict(s), st)
    return st, in_active & in_passive


# ---------------------------------------------------------------------------
# the step + runner
# ---------------------------------------------------------------------------

def make_step(ctx: SimContext, assumed_p, fdp_rate, page_rate):
    """Build the per-write scan step. assumed_p/fdp_rate: [G] policy arrays
    (FDP's fixed assumptions); page_rate: [LBA] true per-page update rates
    (oracle detector input). All may be traced values."""
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block
    bloom_ctx = {"fdp_rate": fdp_rate}

    def demote_fn(st, lba, g):
        return _target_group_gc(ctx, st, lba, g, page_rate, bloom_ctx)

    def step(st, lba):
        st, old_g = _invalidate(st, lba)
        st, g = _target_group_app(ctx, st, lba, old_g, page_rate, bloom_ctx)
        g = jnp.where(st["grp_active"][g], g, old_g)

        # GC when the group needs a new block it is not entitled to, or the
        # pool is at reserve.
        blk = st["active_blk"][g]
        needs_block = jnp.where(
            blk >= 0, st["fill"][jnp.maximum(blk, 0)] >= b, True
        )
        free_blocks = jnp.sum(st["state"] == FREE)
        over_budget = st["grp_phys"][g] >= st["grp_alloc"][g]
        low_pool = free_blocks <= mcfg.gc_reserve_blocks
        do_gc = needs_block & (over_budget | low_pool)
        st = jax.lax.cond(
            do_gc, lambda s: _gc_one(ctx, s, g, demote_fn), lambda s: dict(s), st
        )

        # emergency valve: if the pool is (nearly) empty, greedily reclaim
        # from the fullest group until headroom returns (bounded loop; only
        # fires when a policy briefly overdraws its budget).
        def needs_air(carry):
            s, tries = carry
            return (jnp.sum(s["state"] == FREE) < 2) & (tries < 4)

        def reclaim(carry):
            s, tries = carry
            # global greedy: the best victim anywhere (its group pays)
            closed = s["state"] == CLOSED
            score = jnp.where(closed, s["live"], INT_MAX)
            victim = jnp.argmin(score)
            g_v = jnp.maximum(s["group_of"][victim], 0)
            greedy_ctx = dataclasses.replace(
                ctx, mcfg=dataclasses.replace(ctx.mcfg, gc_policy="greedy")
            )
            return _gc_one(greedy_ctx, s, g_v, demote_fn), tries + 1

        st, _ = jax.lax.while_loop(needs_air, reclaim, (st, 0))

        st = _write_page(ctx, st, lba, g, is_migration=False)
        st["n_app"] = st["n_app"] + 1
        st["grp_writes"] = st["grp_writes"].at[g].add(1)

        # movement operations (§5.3): one compaction GC per step on the most
        # surplus group, donating the redeemed block to the pool.
        if mcfg.movement_ops:
            surplus = jnp.where(
                st["grp_active"], st["grp_phys"] - st["grp_alloc"], -INT_MAX
            )
            g_s = jnp.argmax(surplus)
            pool_ok = jnp.sum(st["state"] == FREE) >= 2  # migration headroom
            st = jax.lax.cond(
                (surplus[g_s] >= 1) & pool_ok,
                lambda s: _gc_one(ctx, s, g_s, demote_fn),
                lambda s: dict(s),
                st,
            )

        # interval completion (§5.1)
        is_interval = (st["n_app"] % ctx.h) == 0
        st = jax.lax.cond(
            is_interval,
            lambda s: _interval_update(ctx, s, assumed_p),
            lambda s: dict(s),
            st,
        )
        return st, (st["n_app"], st["n_mig"])

    return step


@functools.partial(jax.jit, static_argnames=("ctx",))
def _run_jit(ctx: SimContext, st, lbas, page_rate, assumed_p, fdp_rate):
    step = make_step(ctx, assumed_p, fdp_rate, page_rate)
    return jax.lax.scan(step, st, lbas)


def run(ctx: SimContext, st, lbas, *, page_rate=None, assumed_p=None, fdp_rate=None):
    """Run the simulator over a segment of writes.

    lbas: int32 [T]; page_rate: float32 [LBA] true per-page update rates
    (oracle detector modes). Returns (final_state, trace dict of CUMULATIVE
    counters [T]) — segment the workload (e.g. at a frequency swap) by
    calling run() repeatedly with updated oracle arrays.
    """
    lbas = jnp.asarray(lbas, jnp.int32)
    g_max = ctx.mcfg.max_groups
    if page_rate is None:
        page_rate = jnp.zeros(ctx.geom.lba_pages, jnp.float32)
    assumed_p = (
        jnp.zeros(g_max, jnp.float32)
        if assumed_p is None
        else jnp.asarray(assumed_p, jnp.float32)
    )
    fdp_rate = (
        jnp.zeros(g_max, jnp.float32)
        if fdp_rate is None
        else jnp.asarray(fdp_rate, jnp.float32)
    )
    st, (app, mig) = _run_jit(
        ctx, st, lbas, jnp.asarray(page_rate, jnp.float32), assumed_p, fdp_rate
    )
    return st, {"app": app, "mig": mig}


def init_bloom(ctx: SimContext, st):
    """Size the per-group bloom filter pair (only needed for td_mode=bloom)."""
    bits = max(
        64,
        ctx.geom.lba_pages * ctx.mcfg.bloom_bits_per_page // ctx.mcfg.max_groups,
    )
    g_max = ctx.mcfg.max_groups
    st = dict(st)
    st["bloom_active"] = jnp.zeros((g_max, bits), bool)
    st["bloom_passive"] = jnp.zeros((g_max, bits), bool)
    return st
