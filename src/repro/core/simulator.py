"""Write-granularity SSD simulator (jittable, lax.scan over writes).

One scan step = one application write:
  1. invalidate the page's old physical slot (one gather in the packed
     ``page_map``),
  2. pick the target group (temperature detection, §5.6 / oracle),
  3. garbage-collect inside the group if it's out of budgeted space (§5.4),
  4. append the page to the group's active block,
  5. every h writes: interval bookkeeping (§5.1) — EWMA update frequencies,
     re-allocate over-provisioning (§5.5), create/merge groups (§5.2),
  6. movement operations (§5.3): ≤1 proactive compaction GC per step on the
     most block-surplus group, donating redeemed blocks to the pool.

Architecture (post bulk-GC refactor):

* **State** is a :class:`repro.core.ssd.SimState` — a frozen dataclass
  registered as a JAX pytree. Mutating helpers return successors via
  ``st.replace(...)``; there are no ad-hoc ``dict(st)`` copies. The
  logical→physical map is ONE packed int32 array (``page_map = blk · B +
  slot``, ``-1`` unmapped): lookups, invalidates, and writes each cost a
  single gather/scatter instead of the former ``map_blk``/``map_slot`` pair.

* **GC drains in bulk.** :func:`_gc_drain_bulk` migrates a victim's live
  pages in one shot: the ``[B]`` ``slot_lba``/``valid`` lanes are read at
  once, per-slot target groups come from the demotion policy, pages are
  segment-counted per target group, fresh blocks are claimed up front (one
  per overflowing target group, in the exact order the sequential pop would
  produce), and the landings are chunked writes — dense one-hot masked ops
  for the group/block-sized updates (XLA:CPU expands vector-index ``.at[]``
  scatters into a while loop each, measured at ~4× the whole drain's cost)
  and flat 1-D scatters for the two capacity-sized ones. The slot-content
  copy itself routes through ``kernels/gc_compact`` (Pallas-backed on TPU,
  the flattened-index lowering elsewhere). Only the *demotion
  decision* keeps a sequential flavor: §5.6 demotion reads hit rates, which
  drift as the drain moves pages, so when any page is demotion-flagged a
  ``lax.scan`` carrying just the [G] group sizes replays the per-page
  decisions bit-exactly (sort-free; the common static-detector case
  short-circuits to constant targets). No ``fori_loop`` over victim slots
  remains; the former per-page path survives as
  :func:`_gc_drain_reference` (``SimContext.gc_impl="reference"``) and is
  asserted elementwise-identical in tests/test_bulk_gc.py.

* **Policy switches are traced data.** Allocation mode, GC policy, detector,
  movement/dynamic flags — and, since this refactor, the §5.1 constants
  ``ewma_a`` and the interval length ``h`` — live in a per-drive ``policy``
  pytree of scalars/vectors selected with ``lax.cond``/``lax.switch``. Under
  plain jit the predicates stay runtime branches; under ``jax.vmap`` they
  lower to selects, which is what lets ``core/fleet.py`` batch drives with
  *different* manager configs (now including EWMA/interval sweeps) into one
  jitted ``vmap(lax.scan)``. When every drive of a fleet shares ``h``, the
  interval predicate stays a scalar (``SimContext.per_drive_interval=False``)
  so the §5.1 bookkeeping remains a real every-h-steps branch, not a
  per-step select.

GC migrations re-enter the same write semantics (so migrated pages can be
demoted by the detector, as in Listing 1/3 of the paper).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.allocation import (
    allocate_by_frequency,
    allocate_by_size,
    allocate_closed_form,
)
from repro.core.ssd import (
    CLOSED,
    FREE,
    OPEN,
    Geometry,
    ManagerConfig,
    SimState,
    bloom_bits,
)
from repro.kernels.gc_compact.ops import compact_slots

INT_MAX = jnp.iinfo(jnp.int32).max

# policy codes (traced per-drive scalars; see policy_from_config)
ALLOC_CLOSED, ALLOC_FDP, ALLOC_SIZE, ALLOC_FREQ = 0, 1, 2, 3
_ALLOC_CODES = {
    "wolf": ALLOC_CLOSED,
    "optimal": ALLOC_CLOSED,
    "fdp_assumed": ALLOC_FDP,
    "size": ALLOC_SIZE,
    "freq": ALLOC_FREQ,
    "single": ALLOC_SIZE,
}
TD_STATIC, TD_FDP, TD_BLOOM = 0, 1, 2
_TD_CODES = {"static": TD_STATIC, "fdp": TD_FDP, "bloom": TD_BLOOM}


@dataclasses.dataclass(frozen=True)
class SimContext:
    """Static context threaded through the jitted step.

    Holds the SHAPE-defining geometry and the scalar paper constants shared
    by every drive of a fleet; everything that may differ per drive lives in
    the traced ``policy`` pytree.
    """

    geom: Geometry
    mcfg: ManagerConfig
    n_groups: int  # initial groups (may grow in dynamic mode)
    # static because it gates array SHAPES and traced branches: when False
    # the bloom detector branch is structurally absent (vmapped fleets then
    # never pay per-step selects over the [G, bits] filter pair) and the
    # state carries (G, 1) placeholders
    use_bloom: bool = True
    # GC drain implementation: "bulk" (vectorized, default) or "reference"
    # (the per-page fori_loop it replaced — kept as the equivalence oracle)
    gc_impl: str = "bulk"
    # static because it gates the interval predicate's batching: False keeps
    # ((t+1) % h == 0) a SCALAR under vmap (every drive shares h, the §5.1
    # work stays a real branch); True reads the per-drive policy["h"], which
    # under vmap turns the interval machinery into per-step selects — only
    # fleets actually sweeping the interval length pay that
    per_drive_interval: bool = False

    @property
    def h(self) -> int:
        return max(16, int(self.geom.lba_pages * self.mcfg.interval_frac))

    @property
    def f_min_pages(self) -> int:
        return self.geom.n_luns * self.geom.pages_per_block


def policy_from_config(ctx: SimContext, assumed_p=None, fdp_rate=None) -> dict:
    """Lower a ManagerConfig's policy switches to a traced pytree.

    assumed_p/fdp_rate: [G] FDP fixed-assumption arrays (zeros if unused).
    """
    g_max = ctx.mcfg.max_groups
    if assumed_p is None:
        assumed_p = jnp.zeros(g_max, jnp.float32)
    if fdp_rate is None:
        fdp_rate = jnp.zeros(g_max, jnp.float32)
    assert ctx.use_bloom or ctx.mcfg.td_mode != "bloom", (
        "bloom detector requested but ctx.use_bloom is False"
    )
    return {
        "alloc_mode": jnp.asarray(_ALLOC_CODES[ctx.mcfg.alloc_mode], jnp.int32),
        "gc_lru": jnp.asarray(ctx.mcfg.gc_policy == "lru"),
        "movement_ops": jnp.asarray(ctx.mcfg.movement_ops),
        "td_mode": jnp.asarray(_TD_CODES[ctx.mcfg.td_mode], jnp.int32),
        "dynamic_groups": jnp.asarray(ctx.mcfg.dynamic_groups),
        "max_groups": jnp.asarray(ctx.mcfg.max_groups, jnp.int32),
        "f_min_pages": jnp.asarray(ctx.f_min_pages, jnp.int32),
        # §5.1 constants as per-drive sweep axes (ROADMAP: online frequency
        # re-estimation); h doubles as the interval predicate when
        # ctx.per_drive_interval is True
        "h": jnp.asarray(ctx.h, jnp.int32),
        "ewma_a": jnp.asarray(ctx.mcfg.ewma_a, jnp.float32),
        "assumed_p": jnp.asarray(assumed_p, jnp.float32),
        "fdp_rate": jnp.asarray(fdp_rate, jnp.float32),
    }


# ---------------------------------------------------------------------------
# primitive state updates
# ---------------------------------------------------------------------------

def _pop_free_block(st: SimState, g):
    """Claim a FREE block for group g (becomes its OPEN active block)."""
    free_mask = st.state == FREE
    blk = jnp.argmax(free_mask)  # reserve logic upstream guarantees ≥1
    ok = free_mask[blk]
    st = st.replace(
        state=st.state.at[blk].set(jnp.where(ok, OPEN, st.state[blk])),
        group_of=st.group_of.at[blk].set(jnp.where(ok, g, st.group_of[blk])),
        fill=st.fill.at[blk].set(jnp.where(ok, 0, st.fill[blk])),
        grp_phys=st.grp_phys.at[g].add(jnp.where(ok, 1, 0)),
        # LRU clock: a block's age is its claim time — "least recently
        # erased" degenerates into cleaning freshly-filled (never-erased)
        # blocks if ages only advance on erase.
        stamp=st.stamp.at[blk].set(jnp.where(ok, st.clock, st.stamp[blk])),
        clock=st.clock + jnp.where(ok, 1, 0),
    )
    return st, blk, ok


def _write_page(ctx: SimContext, st: SimState, lba, g, *, is_migration: bool,
                enabled=True):
    """Append page `lba` to group g's active block (allocating if needed).

    enabled: traced mask — when False every update is an elementwise no-op.
    The reference GC drain uses this instead of wrapping the call in
    lax.cond, which under vmap would select over the whole state pytree per
    page.
    """
    b = ctx.geom.pages_per_block
    blk = st.active_blk[g]
    blk_full = jnp.where(blk >= 0, st.fill[jnp.maximum(blk, 0)] >= b, True)

    def alloc(st):
        old = st.active_blk[g]
        # seal the previous active block
        st = st.replace(
            state=st.state.at[jnp.maximum(old, 0)].set(
                jnp.where(old >= 0, CLOSED, st.state[jnp.maximum(old, 0)])
            )
        )
        st, new_blk, ok = _pop_free_block(st, g)
        return st.replace(
            active_blk=st.active_blk.at[g].set(jnp.where(ok, new_blk, old))
        )

    st = jax.lax.cond(blk_full & enabled, alloc, lambda s: s, st)
    blk = st.active_blk[g]
    slot = st.fill[blk]
    # overflow guard: if the pool was empty the active block may still be
    # full — drop the write and count it (tests assert this never fires).
    ok = enabled & (blk >= 0) & (slot < b)
    blk_c = jnp.maximum(blk, 0)
    slot_c = jnp.minimum(slot, b - 1)
    updates = dict(
        fill=st.fill.at[blk_c].add(jnp.where(ok, 1, 0)),
        slot_lba=st.slot_lba.at[blk_c, slot_c].set(
            jnp.where(ok, lba, st.slot_lba[blk_c, slot_c])
        ),
        valid=st.valid.at[blk_c, slot_c].set(
            jnp.where(ok, True, st.valid[blk_c, slot_c])
        ),
        live=st.live.at[blk_c].add(jnp.where(ok, 1, 0)),
        # a FAILED (enabled but not ok) write unmaps the page; a disabled
        # call must leave the mapping untouched
        page_map=st.page_map.at[lba].set(
            jnp.where(ok, blk * b + slot,
                      jnp.where(enabled, -1, st.page_map[lba]))
        ),
        grp_size=st.grp_size.at[g].add(jnp.where(ok, 1, 0)),
        n_dropped=st.n_dropped + jnp.where(ok | jnp.logical_not(enabled), 0, 1),
    )
    if is_migration:
        updates["n_mig"] = st.n_mig + jnp.where(ok, 1, 0)
    return st.replace(**updates)


def _invalidate(ctx: SimContext, st: SimState, lba):
    b = ctx.geom.pages_per_block
    pm = st.page_map[lba]
    has = pm >= 0
    pm_c = jnp.maximum(pm, 0)
    blk_c = pm_c // b
    slot = pm_c % b
    old_g = st.group_of[blk_c]
    st = st.replace(
        valid=st.valid.at[blk_c, slot].set(
            jnp.where(has, False, st.valid[blk_c, slot])
        ),
        live=st.live.at[blk_c].add(jnp.where(has, -1, 0)),
        grp_size=st.grp_size.at[jnp.maximum(old_g, 0)].add(
            jnp.where(has & (old_g >= 0), -1, 0)
        ),
    )
    return st, jnp.where(has, old_g, 0)


# ---------------------------------------------------------------------------
# garbage collection (one victim) — §5.4
# ---------------------------------------------------------------------------

def _select_victim(ctx: SimContext, st: SimState, g, gc_lru):
    closed = (st.state == CLOSED) & (st.group_of == g)
    score_lru = jnp.where(closed, st.stamp, INT_MAX)
    score_greedy = jnp.where(closed, st.live, INT_MAX)
    victim = jnp.argmin(jnp.where(gc_lru, score_lru, score_greedy))
    # a fully-live greedy victim frees nothing: skip (movement-op no-op guard)
    ok = closed[victim] & (
        gc_lru | (st.live[victim] < ctx.geom.pages_per_block)
    )
    return victim, ok


def _gc_drain_bulk(ctx: SimContext, st: SimState, victim, g, policy, rate_fn):
    """Vectorized victim drain: migrate every live page in one shot.

    Elementwise-identical to :func:`_gc_drain_reference` whenever no write
    is dropped mid-drain (the pool-reserve invariant callers maintain;
    tests assert ``n_dropped == 0``). The only sequential remnant is the
    demotion decision below — everything that lands state is a chunked
    gather/scatter.
    """
    b = ctx.geom.pages_per_block
    k = ctx.geom.n_blocks
    g_max = st.grp_active.shape[0]
    lba_pages = st.page_map.shape[0]
    g32 = jnp.asarray(g, jnp.int32)

    lbas = st.slot_lba[victim]            # [B]; dead slots hold -1
    is_live = st.valid[victim]            # [B]
    lbas_c = jnp.maximum(lbas, 0)
    n_live = jnp.sum(is_live)

    # -- per-slot DEMOTION FLAGS (§5.6), vectorized over the victim's lanes.
    # A GC demotion only ever moves a page one group colder, and whether a
    # page is demotion-eligible depends solely on drain-invariant state
    # (oracle rates, fdp bands, the bloom filter pair) — so it precomputes
    # as one [B] mask. Keeping the big state arrays out of the per-slot
    # machinery below matters: anything a lax.scan/switch touches is hauled
    # through the loop boundary every iteration on XLA:CPU.
    def static_flags(lbas_c):
        return jnp.zeros(b, bool)

    def fdp_flags(lbas_c):
        r = jax.vmap(lambda l: rate_fn(st, l))(lbas_c)
        return r < 0.5 * policy["fdp_rate"][g]

    def bloom_flags(lbas_c):
        in_a = jax.vmap(
            lambda l: _bloom_query(ctx, st.bloom_active, l, g)
        )(lbas_c)
        in_p = jax.vmap(
            lambda l: _bloom_query(ctx, st.bloom_passive, l, g)
        )(lbas_c)
        return ~in_a & ~in_p

    flag_branches = [static_flags, fdp_flags]
    if ctx.use_bloom:
        flag_branches.append(bloom_flags)
    demote_flag = jax.lax.switch(policy["td_mode"], flag_branches, lbas_c)

    # -- per-slot target groups, exact sequential semantics. A demoted page
    # lands one group colder BY CURRENT HIT-RATE ORDER, and hit rates
    # (grp_p / grp_size) drift as the drain itself moves pages — so when any
    # page is flagged, a lax.scan carrying ONLY the [G] group sizes replays
    # the per-page neighbor decisions bit-exactly. The common case (static
    # detector / nothing flagged) short-circuits to constant targets.
    grp_p, grp_active = st.grp_p, st.grp_active

    def const_targets(_):
        return jnp.full(b, g32)

    arange_g = jnp.arange(g_max, dtype=jnp.int32)

    def scan_targets(_):
        def body(gs, xs):
            flag, live = xs
            # _hit_rates over the drifted sizes, [G]-sized
            hr = jnp.where(
                grp_active,
                grp_p / jnp.maximum(gs.astype(jnp.float32), 1.0),
                -1.0,
            )
            # next-colder ACTIVE group by current hit-rate order — the
            # reductions replicate _sgv_neighbors' stable argsort (ties
            # break by index): the candidate set is every active group
            # strictly after g in (-hr, index) lexicographic order, and
            # the neighbor is its (max hr, then min index) element. No
            # sort: a batched XLA:CPU sort 16×/drain dominates the drain.
            hr_g = hr[g]
            cand = grp_active & (
                (hr < hr_g) | ((hr == hr_g) & (arange_g > g32))
            )
            best_hr = jnp.max(jnp.where(cand, hr, -2.0))
            nb = jnp.min(
                jnp.where(cand & (hr == best_hr), arange_g, g_max)
            )
            # empty candidate set: an active g is already the coldest and
            # stays put; an inactive g (post-merge corner) falls to the
            # coldest active — exactly argsort's clip(rank+1, n_active-1)
            cold_hr = jnp.min(jnp.where(grp_active, hr, jnp.inf))
            coldest = jnp.max(
                jnp.where(grp_active & (hr == cold_hr), arange_g, -1)
            )
            fallback = jnp.where(grp_active[g], g32, coldest)
            nb = jnp.where(jnp.any(cand), nb, fallback)
            t = jnp.where(flag & live, nb, g32).astype(jnp.int32)
            gs = gs.at[g].add(jnp.where(live, -1, 0)).at[t].add(
                jnp.where(live, 1, 0)
            )
            return gs, t

        _, ts = jax.lax.scan(body, st.grp_size, (demote_flag, is_live))
        return ts

    targets = jax.lax.cond(
        jnp.any(demote_flag & is_live), scan_targets, const_targets, 0
    )
    t_live = jnp.where(is_live, targets, g_max)  # dead rows → masked out

    # NOTE on lowering: XLA:CPU's scatter expander rewrites every multi-row
    # .at[] scatter into a while loop (measured: ~14 scatters/drain → ~40
    # extra loops, ~70µs, 4× the whole drain). Group/block-sized updates
    # below therefore use DENSE one-hot masked ops ([b,G]/[G,K]/[b,K] —
    # tiny, they fuse); only the two capacity-sized updates (page_map and
    # the compact_slots pool copy) stay 1-D scatters, where ONE expanded
    # loop per drain beats a capacity-wide mask. Scalar-index updates (the
    # victim erase) lower to dynamic-update-slice and are free either way.
    arange_k = jnp.arange(k, dtype=jnp.int32)
    idx = jnp.arange(b, dtype=jnp.int32)

    # -- segment-count pages per target group; claim fresh blocks up front.
    # A victim holds ≤ B live pages, so each target group claims at most ONE
    # fresh block per drain; the i-th claim (ordered by the slot position of
    # the first non-fitting page) takes the i-th lowest-index FREE block —
    # exactly what the sequential argmax-pop produces.
    onehot_t = t_live[:, None] == arange_g[None, :]  # [b, G], live rows only
    m = jnp.sum(onehot_t, axis=0, dtype=jnp.int32)   # pages per target group
    ab = st.active_blk
    has_ab = ab >= 0
    ab_c = jnp.maximum(ab, 0)
    fill_ab = jnp.where(has_ab, st.fill[ab_c], b)
    space = b - jnp.minimum(fill_ab, b)   # free slots in the active block
    claim = m > space                     # group needs a fresh block
    seal = claim & has_ab                 # …sealing its current active

    # within-group rank of each live page, in slot order
    same = (
        (targets[:, None] == targets[None, :])
        & is_live[None, :] & is_live[:, None]
    )
    rank = jnp.sum(same & (idx[None, :] < idx[:, None]), axis=1)

    is_claim_pg = is_live & (rank == space[targets])
    claim_pos = jnp.min(
        jnp.where(onehot_t & is_claim_pg[:, None], idx[:, None], INT_MAX),
        axis=0,
    )  # [G] slot position of each group's claim
    claim_rank = jnp.sum(
        claim[None, :] & (claim_pos[None, :] < claim_pos[:, None]), axis=1
    )
    free_mask = st.state == FREE
    n_free = jnp.sum(free_mask)
    # free_by_rank[r] = r-th lowest FREE block index (what the sequential
    # argmax-pop hands out); an XLA:CPU sort here would cost ~100µs/drain
    frank = jnp.cumsum(free_mask) - 1  # free-rank of each free block
    free_by_rank = jnp.min(
        jnp.where(
            free_mask[None, :] & (frank[None, :] == arange_g[:, None]),
            arange_k[None, :], k,
        ),
        axis=1,
    )  # [G]
    claim_ok = claim & (claim_rank < n_free)  # pool-exhausted claims fail
    new_blk = jnp.where(
        claim_ok, free_by_rank[jnp.minimum(claim_rank, g_max - 1)], -1
    )

    # -- per-page destinations ---------------------------------------------
    space_p = space[targets]
    in_old = rank < space_p
    dst_blk = jnp.where(in_old, ab_c[targets], new_blk[targets])
    dst_slot = jnp.where(in_old, fill_ab[targets] + rank, rank - space_p)
    ok = is_live & (in_old | claim_ok[targets])
    db = jnp.where(ok, dst_blk, k)        # masked rows land nowhere

    # -- seal / claim bookkeeping ------------------------------------------
    seal_mask = jnp.any(
        (ab_c[None, :] == arange_k[:, None]) & seal[None, :], axis=1
    )  # [K]
    claim_onehot = (
        (new_blk[None, :] == arange_k[:, None]) & claim_ok[None, :]
    )  # [K, G]
    claim_mask = jnp.any(claim_onehot, axis=1)
    state_a = jnp.where(seal_mask, CLOSED, st.state)
    state_a = jnp.where(claim_mask, OPEN, state_a)
    group_of = jnp.where(
        claim_mask, jnp.sum(claim_onehot * arange_g[None, :], axis=1),
        st.group_of,
    )
    stamp = jnp.where(
        claim_mask,
        jnp.sum(claim_onehot * (st.clock + claim_rank)[None, :], axis=1),
        st.stamp,
    )
    clock = st.clock + jnp.sum(claim_ok)
    grp_phys = st.grp_phys + claim_ok.astype(jnp.int32)
    active_blk = jnp.where(claim_ok, new_blk, ab)

    # -- land the pages (dense chunked writes) ------------------------------
    dst_onehot = db[:, None] == arange_k[None, :]    # [b, K], ok rows only
    dst_count = jnp.sum(dst_onehot, axis=0, dtype=jnp.int32)
    fill_a = jnp.where(claim_mask, 0, st.fill) + dst_count
    live_a = st.live + dst_count
    # the slot-content copy (victim slots → destination slots) is the GC
    # kernel's move list: Pallas-backed on TPU, dense one-hot writes off-TPU
    slot_lba, valid = compact_slots(
        st.slot_lba, st.valid,
        jnp.where(ok, victim, -1), idx, db, dst_slot,
    )
    # 1-D scatter, not a [b, LBA] one-hot: a dense mask here would scale
    # with drive capacity, and a single expanded scatter loop per site is
    # measurably cheaper than the capacity-wide mask even at test geometry
    page_map = st.page_map.at[jnp.where(is_live, lbas_c, lba_pages)].set(
        jnp.where(ok, dst_blk * b + dst_slot, -1), mode="drop"
    )  # dead slots land out of bounds → untouched
    grp_size = (
        st.grp_size.at[g].add(-n_live)
        + jnp.sum(onehot_t & ok[:, None], axis=0, dtype=jnp.int32)
    )

    # -- erase the victim ---------------------------------------------------
    return st.replace(
        state=state_a.at[victim].set(FREE),
        group_of=group_of.at[victim].set(-1),
        fill=fill_a.at[victim].set(0),
        live=live_a.at[victim].set(0),
        slot_lba=slot_lba.at[victim].set(-1),
        valid=valid.at[victim].set(False),
        stamp=stamp.at[victim].set(clock),
        clock=clock + 1,
        grp_phys=grp_phys.at[g].add(-1),
        active_blk=active_blk,
        page_map=page_map,
        grp_size=grp_size,
        n_mig=st.n_mig + jnp.sum(ok),
        n_dropped=st.n_dropped + jnp.sum(is_live & jnp.logical_not(ok)),
        n_erase=st.n_erase + 1,
    )


def _gc_drain_reference(ctx: SimContext, st: SimState, victim, g, demote_fn):
    """The pre-refactor per-page drain (16-step fori of single-page writes).

    Kept as the equivalence oracle for :func:`_gc_drain_bulk`
    (tests/test_bulk_gc.py); never on the default path.
    """
    b = ctx.geom.pages_per_block

    def body(j, st):
        # masked migration (no lax.cond: under vmap a per-slot cond would
        # select over the whole state pytree B×/GC)
        lba = st.slot_lba[victim, j]
        is_live = st.valid[victim, j]
        lba_c = jnp.maximum(lba, 0)  # dead slots hold -1
        st = st.replace(
            valid=st.valid.at[victim, j].set(
                jnp.where(is_live, False, st.valid[victim, j])
            ),
            live=st.live.at[victim].add(jnp.where(is_live, -1, 0)),
        )
        g_tgt = demote_fn(st, lba_c, g)  # pure read of st
        st = st.replace(
            grp_size=st.grp_size.at[g].add(jnp.where(is_live, -1, 0))
        )
        return _write_page(
            ctx, st, lba_c, g_tgt, is_migration=True, enabled=is_live
        )

    st = jax.lax.fori_loop(0, b, body, st)
    # erase
    return st.replace(
        state=st.state.at[victim].set(FREE),
        group_of=st.group_of.at[victim].set(-1),
        fill=st.fill.at[victim].set(0),
        live=st.live.at[victim].set(0),
        slot_lba=st.slot_lba.at[victim].set(-1),
        valid=st.valid.at[victim].set(False),
        stamp=st.stamp.at[victim].set(st.clock),
        clock=st.clock + 1,
        grp_phys=st.grp_phys.at[g].add(-1),
        n_erase=st.n_erase + 1,
    )


def _gc_one(ctx: SimContext, st: SimState, g, policy, rate_fn, gc_lru):
    """GC one victim in group g; migrate live pages via the bulk drain.

    rate_fn(st, lba) -> the page's true update rate (oracle detector input);
    must be a pure function of drain-invariant data (it is: oracle arrays
    are indexed by lba/phase only). The §5.6 demotion rule itself is
    derived from ``policy`` — see _gc_drain_bulk / _target_group_gc.
    """
    assert ctx.gc_impl in ("bulk", "reference"), ctx.gc_impl
    victim, ok = _select_victim(ctx, st, g, gc_lru)
    # migrations may need one fresh block beyond the active's free slots:
    # never start a GC with an empty pool (callers keep it ≥ 2).
    ok = ok & (jnp.sum(st.state == FREE) >= 1)
    if ctx.gc_impl == "bulk":
        def drain(s):
            return _gc_drain_bulk(ctx, s, victim, g, policy, rate_fn)
    else:
        def demote_fn(s, l, gg):
            return _target_group_gc(ctx, s, l, gg, policy, rate_fn)

        def drain(s):
            return _gc_drain_reference(ctx, s, victim, g, demote_fn)

    return jax.lax.cond(ok, drain, lambda s: s, st)


# ---------------------------------------------------------------------------
# over-provisioning allocation (interval) — §5.5
# ---------------------------------------------------------------------------

def _recompute_alloc(ctx: SimContext, st: SimState, policy):
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block
    active = st.grp_active
    s = jnp.where(active, st.grp_size.astype(jnp.float32), 0.0)
    s = jnp.maximum(s, jnp.where(active, 1.0, 0.0))
    use_assumed = policy["alloc_mode"] == ALLOC_FDP
    p = jnp.where(
        active, jnp.where(use_assumed, policy["assumed_p"], st.grp_p), 0.0
    )
    p = p / jnp.maximum(p.sum(), 1e-9)
    # usable OP = spare pages beyond logical content, minus the GC reserve
    # and one block per active group (absorbs the per-group ceil slack so
    # the budgets can never collectively over-claim the pool)
    n_active = active.sum()
    op_total = (
        jnp.asarray(geom.pba_pages, jnp.float32)
        - (mcfg.gc_reserve_blocks + 1 + n_active) * b
        - s.sum()
    )

    op_closed = allocate_closed_form(
        s, p, op_total,
        cold_rule=True,
        cold_hit_rate_frac=mcfg.cold_hit_rate_frac,
        cold_op_frac=mcfg.cold_op_frac,
    )
    op_size = allocate_by_size(s, op_total)
    op_freq = allocate_by_frequency(p, op_total)
    is_closed = (policy["alloc_mode"] == ALLOC_CLOSED) | use_assumed
    is_freq = policy["alloc_mode"] == ALLOC_FREQ
    op = jnp.where(is_closed, op_closed, jnp.where(is_freq, op_freq, op_size))
    alloc_blocks = jnp.ceil((s + op) / b).astype(jnp.int32)
    alloc_blocks = jnp.where(active, jnp.maximum(alloc_blocks, 1), 0)
    return st.replace(grp_alloc=alloc_blocks)


def _interval_update(ctx: SimContext, st: SimState, policy):
    a = policy["ewma_a"]
    u = st.grp_writes.astype(jnp.float32) / policy["h"].astype(jnp.float32)
    active = st.grp_active
    st = st.replace(
        grp_p=jnp.where(active, st.grp_p * (1.0 - a) + a * u, 0.0),
        grp_writes=jnp.zeros_like(st.grp_writes),
        interval=st.interval + 1,
        cooldown=jnp.maximum(st.cooldown - 1, 0),
    )
    st = _maybe_create_or_merge(ctx, st, policy)
    st = _recompute_alloc(ctx, st, policy)
    return st


# ---------------------------------------------------------------------------
# group creation / merging (dynamic mode) — §5.2
# ---------------------------------------------------------------------------

def _hit_rates(st: SimState):
    s = jnp.maximum(st.grp_size.astype(jnp.float32), 1.0)
    hr = st.grp_p / s
    return jnp.where(st.grp_active, hr, -1.0)


def _maybe_create_or_merge(ctx: SimContext, st: SimState, policy):
    mcfg = ctx.mcfg
    dynamic = policy["dynamic_groups"]
    f_min = policy["f_min_pages"]
    hr = _hit_rates(st)
    order = jnp.argsort(-hr)  # hottest first
    hottest, second = order[0], order[1]
    n_active = st.grp_active.sum()
    can_slot = n_active < policy["max_groups"]
    hot_ratio = hr[hottest] / jnp.maximum(hr[second], 1e-12)
    create = (
        dynamic
        & can_slot
        & (st.cooldown == 0)
        & (n_active >= 2)
        & (hot_ratio >= mcfg.q_create)
        & (st.grp_size[hottest] >= f_min)
    )

    def do_create(st):
        slot = jnp.argmin(st.grp_active)  # first inactive slot
        return st.replace(
            grp_active=st.grp_active.at[slot].set(True),
            # seed stats: half the hottest group's measured frequency
            grp_p=st.grp_p.at[slot].set(st.grp_p[hottest] * 0.5),
            grp_size=st.grp_size.at[slot].set(0),
            grp_phys=st.grp_phys.at[slot].set(0),
            grp_created=st.grp_created.at[slot].set(st.interval),
            cooldown=jnp.asarray(mcfg.w_intervals, jnp.int32),
        )

    st = jax.lax.cond(create, do_create, lambda s: s, st)

    # merge: coldest adjacent pair that converged, or an undersized group
    hr = _hit_rates(st)
    order = jnp.argsort(-hr)
    n_active = st.grp_active.sum()
    # adjacent pair ratios in hit-rate order
    hr_sorted = hr[order]
    idx = jnp.arange(hr.shape[0])
    valid_pair = (idx + 1 < n_active)
    ratio = hr_sorted / jnp.maximum(jnp.roll(hr_sorted, -1), 1e-12)
    converged = valid_pair & (ratio < 1.3) & (hr_sorted > 0)
    tiny = valid_pair & (
        st.grp_size[order] < f_min
    ) & (jnp.roll(hr_sorted, -1) > 0)
    mergeable = converged | tiny
    pair_i = jnp.argmax(mergeable)
    do_merge = (
        dynamic & mergeable[pair_i] & (st.cooldown == 0) & (n_active > 2)
    )

    def merge(st):
        g_from = order[pair_i]          # hotter of the pair
        g_to = order[pair_i + 1]        # absorbed into the colder
        # relabel blocks (the paper: a merge is logical)
        group_of = jnp.where(st.group_of == g_from, g_to, st.group_of)
        # seal g_from's active block (no longer reachable)
        ab = st.active_blk[g_from]
        state_a = st.state.at[jnp.maximum(ab, 0)].set(
            jnp.where(ab >= 0, CLOSED, st.state[jnp.maximum(ab, 0)])
        )
        merged = {}
        for key in ("grp_size", "grp_phys", "grp_p", "grp_writes"):
            arr = getattr(st, key)
            merged[key] = arr.at[g_to].add(arr[g_from]).at[g_from].set(0)
        return st.replace(
            group_of=group_of,
            state=state_a,
            active_blk=st.active_blk.at[g_from].set(-1),
            grp_active=st.grp_active.at[g_from].set(False),
            cooldown=jnp.asarray(mcfg.w_intervals, jnp.int32),
            **merged,
        )

    return jax.lax.cond(do_merge, merge, lambda s: s, st)


# ---------------------------------------------------------------------------
# temperature detection — §5.6 (+ oracle modes for §6 experiments)
# ---------------------------------------------------------------------------

def _sgv_neighbors(st: SimState):
    """hotter_of[g], colder_of[g] by current hit-rate order."""
    hr = _hit_rates(st)
    g_max = hr.shape[0]
    # rank[g] = position in descending order
    order = jnp.argsort(-hr)
    rank = jnp.zeros(g_max, jnp.int32).at[order].set(jnp.arange(g_max))
    n_active = st.grp_active.sum()

    def neighbor(g, delta):
        r = rank[g] + delta
        r = jnp.clip(r, 0, n_active - 1)
        return order[r]

    return neighbor


def _target_group_app(ctx: SimContext, st: SimState, lba, cur_g, policy, rate_fn):
    """Target group for an application update of `lba` living in cur_g."""
    cur_g = jnp.asarray(cur_g, jnp.int32)

    def static_br(st):
        return st, cur_g

    def fdp_br(st):
        # fixed assumed per-page rate bands: promote if ≥2× the group's
        # assumed rate (paper §5/§6: FDP's fixed-order assumption)
        neighbor = _sgv_neighbors(st)
        r = rate_fn(st, lba)
        promote = r > 2.0 * policy["fdp_rate"][cur_g]
        g = jnp.where(promote, neighbor(cur_g, -1), cur_g)
        return st, g.astype(jnp.int32)

    def bloom_br(st):
        # bloom (§5.6): in both filters → promote
        st, in_both = _bloom_update(ctx, st, lba, cur_g)
        g = jnp.where(in_both, _sgv_neighbors(st)(cur_g, -1), cur_g)
        return st, g.astype(jnp.int32)

    branches = [static_br, fdp_br]
    if ctx.use_bloom:
        branches.append(bloom_br)
    return jax.lax.switch(policy["td_mode"], branches, st)


def _target_group_gc(ctx: SimContext, st: SimState, lba, cur_g, policy, rate_fn):
    cur_g = jnp.asarray(cur_g, jnp.int32)

    def static_br(st):
        return cur_g

    def fdp_br(st):
        neighbor = _sgv_neighbors(st)
        r = rate_fn(st, lba)
        demote = r < 0.5 * policy["fdp_rate"][cur_g]
        return jnp.where(demote, neighbor(cur_g, +1), cur_g).astype(jnp.int32)

    def bloom_br(st):
        # bloom: in neither filter during a migration → demote
        neighbor = _sgv_neighbors(st)
        in_active = _bloom_query(ctx, st.bloom_active, lba, cur_g)
        in_passive = _bloom_query(ctx, st.bloom_passive, lba, cur_g)
        g = jnp.where(~in_active & ~in_passive, neighbor(cur_g, +1), cur_g)
        return g.astype(jnp.int32)

    branches = [static_br, fdp_br]
    if ctx.use_bloom:
        branches.append(bloom_br)
    return jax.lax.switch(policy["td_mode"], branches, st)


# -- bloom filter pair (per group) ------------------------------------------

def _bloom_hashes(ctx: SimContext, lba):
    bits = bloom_bits(ctx.geom, ctx.mcfg)
    u = lba.astype(jnp.uint32)
    h1 = (u * jnp.uint32(2654435761)) % jnp.uint32(bits)
    h2 = (u * jnp.uint32(40503) + jnp.uint32(99991)) % jnp.uint32(bits)
    return h1.astype(jnp.int32), h2.astype(jnp.int32), bits


def _bloom_query(ctx, filt, lba, g):
    h1, h2, _ = _bloom_hashes(ctx, lba)
    return filt[g, h1] & filt[g, h2]


def _bloom_update(ctx: SimContext, st: SimState, lba, g):
    """Insert lba into group g's active filter; rotate when the group's
    write interval (= group size) elapses. Returns (st, was_in_both)."""
    h1, h2, _ = _bloom_hashes(ctx, lba)
    in_active = st.bloom_active[g, h1] & st.bloom_active[g, h2]
    in_passive = st.bloom_passive[g, h1] & st.bloom_passive[g, h2]
    bloom_active = st.bloom_active.at[g, h1].set(True).at[g, h2].set(True)
    bloom_writes = st.bloom_writes.at[g].add(1)
    rotate = bloom_writes[g] >= jnp.maximum(st.grp_size[g], 64)
    # row-masked rotation (no lax.cond: under vmap a cond would select over
    # the full [G, bits] filter pair every step; this touches one row)
    row_active = bloom_active[g]
    st = st.replace(
        bloom_passive=st.bloom_passive.at[g].set(
            jnp.where(rotate, row_active, st.bloom_passive[g])
        ),
        bloom_active=bloom_active.at[g].set(
            jnp.where(rotate, False, row_active)
        ),
        bloom_writes=bloom_writes.at[g].set(
            jnp.where(rotate, 0, bloom_writes[g])
        ),
    )
    return st, in_active & in_passive


# ---------------------------------------------------------------------------
# the step + runner
# ---------------------------------------------------------------------------

def make_step(ctx: SimContext, policy, rate_fn):
    """Build the per-write scan step.

    policy: traced pytree from :func:`policy_from_config` (per-drive under
    vmap). rate_fn(st, lba, t) -> true per-page update rate of `lba` at
    global write index t (oracle detector input; phase-aware in fleets).
    Scan input = (lba, t); t is the global application-write index, which is
    deliberately NOT taken from batched state so the interval predicate
    stays a scalar under vmap whenever every drive shares h
    (ctx.per_drive_interval=False) — the expensive §5.1 bookkeeping then
    lowers to a real branch taken every h steps, not a per-step select.
    """
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block

    def step(st, xs):
        lba, t = xs

        def lookup(s, l):
            return rate_fn(s, l, t)

        st, old_g = _invalidate(ctx, st, lba)
        st, g = _target_group_app(ctx, st, lba, old_g, policy, lookup)
        g = jnp.where(st.grp_active[g], g, old_g)

        # GC when the group needs a new block it is not entitled to, or the
        # pool is at reserve.
        blk = st.active_blk[g]
        needs_block = jnp.where(
            blk >= 0, st.fill[jnp.maximum(blk, 0)] >= b, True
        )
        free_blocks = jnp.sum(st.state == FREE)
        over_budget = st.grp_phys[g] >= st.grp_alloc[g]
        low_pool = free_blocks <= mcfg.gc_reserve_blocks
        do_gc = needs_block & (over_budget | low_pool)
        st = jax.lax.cond(
            do_gc,
            lambda s: _gc_one(ctx, s, g, policy, lookup, policy["gc_lru"]),
            lambda s: s,
            st,
        )

        # emergency valve: if the pool is (nearly) empty, greedily reclaim
        # from the fullest group until headroom returns (bounded loop; only
        # fires when a policy briefly overdraws its budget).
        def needs_air(carry):
            s, tries = carry
            return (jnp.sum(s.state == FREE) < 2) & (tries < 4)

        def reclaim(carry):
            s, tries = carry
            # global greedy: the best victim anywhere (its group pays)
            closed = s.state == CLOSED
            score = jnp.where(closed, s.live, INT_MAX)
            victim = jnp.argmin(score)
            g_v = jnp.maximum(s.group_of[victim], 0)
            return (
                _gc_one(ctx, s, g_v, policy, lookup, jnp.asarray(False)),
                tries + 1,
            )

        st, _ = jax.lax.while_loop(needs_air, reclaim, (st, 0))

        st = _write_page(ctx, st, lba, g, is_migration=False)
        st = st.replace(
            n_app=st.n_app + 1,
            grp_writes=st.grp_writes.at[g].add(1),
        )

        # movement operations (§5.3): one compaction GC per step on the most
        # surplus group, donating the redeemed block to the pool.
        surplus = jnp.where(
            st.grp_active, st.grp_phys - st.grp_alloc, -INT_MAX
        )
        g_s = jnp.argmax(surplus)
        pool_ok = jnp.sum(st.state == FREE) >= 2  # migration headroom
        st = jax.lax.cond(
            policy["movement_ops"] & (surplus[g_s] >= 1) & pool_ok,
            lambda s: _gc_one(ctx, s, g_s, policy, lookup, policy["gc_lru"]),
            lambda s: s,
            st,
        )

        # interval completion (§5.1); t+1 == n_app after this write, so the
        # predicate is exactly (n_app % h == 0). With a fleet-shared h it is
        # a SCALAR shared by every vmapped drive; per-drive interval sweeps
        # (ctx.per_drive_interval) read the traced policy["h"] instead.
        h = policy["h"] if ctx.per_drive_interval else ctx.h
        is_interval = ((t + 1) % h) == 0
        st = jax.lax.cond(
            is_interval,
            lambda s: _interval_update(ctx, s, policy),
            lambda s: s,
            st,
        )
        return st, (st.n_app, st.n_mig)

    return step


@functools.partial(jax.jit, static_argnames=("ctx",))
def _run_jit(ctx: SimContext, st: SimState, lbas, page_rate, policy):
    def rate_fn(s, lba, t):
        return page_rate[lba]

    step = make_step(ctx, policy, rate_fn)
    ts = st.n_app + jnp.arange(lbas.shape[0], dtype=jnp.int32)
    return jax.lax.scan(step, st, (lbas, ts))


def run(ctx: SimContext, st: SimState, lbas, *, page_rate=None, assumed_p=None,
        fdp_rate=None):
    """Run the simulator over a segment of writes.

    lbas: int32 [T]; page_rate: float32 [LBA] true per-page update rates
    (oracle detector modes). Returns (final_state, trace dict of CUMULATIVE
    counters [T]) — segment the workload (e.g. at a frequency swap) by
    calling run() repeatedly with updated oracle arrays.
    """
    lbas = jnp.asarray(lbas, jnp.int32)
    if page_rate is None:
        page_rate = jnp.zeros(ctx.geom.lba_pages, jnp.float32)
    policy = policy_from_config(ctx, assumed_p, fdp_rate)
    st, (app, mig) = _run_jit(
        ctx, st, lbas, jnp.asarray(page_rate, jnp.float32), policy
    )
    return st, {"app": app, "mig": mig}
