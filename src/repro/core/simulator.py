"""Write-granularity SSD simulator (jittable, lax.scan over writes).

One scan step = one application write:
  1. invalidate the page's old physical slot,
  2. pick the target group (temperature detection, §5.6 / oracle),
  3. garbage-collect inside the group if it's out of budgeted space (§5.4),
  4. append the page to the group's active block,
  5. every h writes: interval bookkeeping (§5.1) — EWMA update frequencies,
     re-allocate over-provisioning (§5.5), create/merge groups (§5.2),
  6. movement operations (§5.3): ≤1 proactive compaction GC per step on the
     most block-surplus group, donating redeemed blocks to the pool.

GC migrations re-enter the same write path (so migrated pages can be demoted
by the detector, as in Listing 1/3 of the paper).

Policy switches (allocation mode, GC policy, detector, movement/dynamic
flags) are TRACED DATA — a per-drive ``policy`` pytree of scalars/vectors —
selected with ``lax.cond``/``lax.switch`` instead of Python branches. Under
plain jit the predicates stay runtime branches (no extra work on the
single-drive path); under ``jax.vmap`` they lower to selects, which is what
lets ``core/fleet.py`` batch drives with *different* manager configs into
one jitted ``vmap(lax.scan)``. State is a flat dict of jnp arrays (a clean
pytree), so the whole simulator jits, vmaps, checkpoints, and scans.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.allocation import (
    allocate_by_frequency,
    allocate_by_size,
    allocate_closed_form,
)
from repro.core.ssd import CLOSED, FREE, OPEN, Geometry, ManagerConfig, bloom_bits

INT_MAX = jnp.iinfo(jnp.int32).max

# policy codes (traced per-drive scalars; see policy_from_config)
ALLOC_CLOSED, ALLOC_FDP, ALLOC_SIZE, ALLOC_FREQ = 0, 1, 2, 3
_ALLOC_CODES = {
    "wolf": ALLOC_CLOSED,
    "optimal": ALLOC_CLOSED,
    "fdp_assumed": ALLOC_FDP,
    "size": ALLOC_SIZE,
    "freq": ALLOC_FREQ,
    "single": ALLOC_SIZE,
}
TD_STATIC, TD_FDP, TD_BLOOM = 0, 1, 2
_TD_CODES = {"static": TD_STATIC, "fdp": TD_FDP, "bloom": TD_BLOOM}


@dataclasses.dataclass(frozen=True)
class SimContext:
    """Static context threaded through the jitted step.

    Holds the SHAPE-defining geometry and the scalar paper constants shared
    by every drive of a fleet; everything that may differ per drive lives in
    the traced ``policy`` pytree.
    """

    geom: Geometry
    mcfg: ManagerConfig
    n_groups: int  # initial groups (may grow in dynamic mode)
    # static because it gates array SHAPES and traced branches: when False
    # the bloom detector branch is structurally absent (vmapped fleets then
    # never pay per-step selects over the [G, bits] filter pair) and the
    # state carries (G, 1) placeholders
    use_bloom: bool = True

    @property
    def h(self) -> int:
        return max(16, int(self.geom.lba_pages * self.mcfg.interval_frac))

    @property
    def f_min_pages(self) -> int:
        return self.geom.n_luns * self.geom.pages_per_block


def policy_from_config(ctx: SimContext, assumed_p=None, fdp_rate=None) -> dict:
    """Lower a ManagerConfig's policy switches to a traced pytree.

    assumed_p/fdp_rate: [G] FDP fixed-assumption arrays (zeros if unused).
    """
    g_max = ctx.mcfg.max_groups
    if assumed_p is None:
        assumed_p = jnp.zeros(g_max, jnp.float32)
    if fdp_rate is None:
        fdp_rate = jnp.zeros(g_max, jnp.float32)
    assert ctx.use_bloom or ctx.mcfg.td_mode != "bloom", (
        "bloom detector requested but ctx.use_bloom is False"
    )
    return {
        "alloc_mode": jnp.asarray(_ALLOC_CODES[ctx.mcfg.alloc_mode], jnp.int32),
        "gc_lru": jnp.asarray(ctx.mcfg.gc_policy == "lru"),
        "movement_ops": jnp.asarray(ctx.mcfg.movement_ops),
        "td_mode": jnp.asarray(_TD_CODES[ctx.mcfg.td_mode], jnp.int32),
        "dynamic_groups": jnp.asarray(ctx.mcfg.dynamic_groups),
        "max_groups": jnp.asarray(ctx.mcfg.max_groups, jnp.int32),
        "f_min_pages": jnp.asarray(ctx.f_min_pages, jnp.int32),
        "assumed_p": jnp.asarray(assumed_p, jnp.float32),
        "fdp_rate": jnp.asarray(fdp_rate, jnp.float32),
    }


# ---------------------------------------------------------------------------
# primitive state updates
# ---------------------------------------------------------------------------

def _pop_free_block(st, g):
    """Claim a FREE block for group g (becomes its OPEN active block)."""
    free_mask = st["state"] == FREE
    blk = jnp.argmax(free_mask)  # reserve logic upstream guarantees ≥1
    ok = free_mask[blk]
    st = dict(st)
    st["state"] = st["state"].at[blk].set(jnp.where(ok, OPEN, st["state"][blk]))
    st["group_of"] = st["group_of"].at[blk].set(
        jnp.where(ok, g, st["group_of"][blk])
    )
    st["fill"] = st["fill"].at[blk].set(jnp.where(ok, 0, st["fill"][blk]))
    st["grp_phys"] = st["grp_phys"].at[g].add(jnp.where(ok, 1, 0))
    # LRU clock: a block's age is its claim time — "least recently erased"
    # degenerates into cleaning freshly-filled (never-erased) blocks if ages
    # only advance on erase.
    st["stamp"] = st["stamp"].at[blk].set(jnp.where(ok, st["clock"], st["stamp"][blk]))
    st["clock"] = st["clock"] + jnp.where(ok, 1, 0)
    return st, blk, ok


def _write_page(ctx: SimContext, st, lba, g, *, is_migration: bool, enabled=True):
    """Append page `lba` to group g's active block (allocating if needed).

    enabled: traced mask — when False every update is an elementwise no-op.
    GC migration loops use this instead of wrapping the call in lax.cond,
    which under vmap would select over the whole state pytree per page.
    """
    b = ctx.geom.pages_per_block
    blk = st["active_blk"][g]
    blk_full = jnp.where(blk >= 0, st["fill"][jnp.maximum(blk, 0)] >= b, True)

    def alloc(st):
        st = dict(st)
        old = st["active_blk"][g]
        # seal the previous active block
        st["state"] = st["state"].at[jnp.maximum(old, 0)].set(
            jnp.where(old >= 0, CLOSED, st["state"][jnp.maximum(old, 0)])
        )
        st, new_blk, ok = _pop_free_block(st, g)
        st["active_blk"] = st["active_blk"].at[g].set(
            jnp.where(ok, new_blk, old)
        )
        return st

    st = jax.lax.cond(blk_full & enabled, alloc, lambda s: dict(s), st)
    blk = st["active_blk"][g]
    slot = st["fill"][blk]
    # overflow guard: if the pool was empty the active block may still be
    # full — drop the write and count it (tests assert this never fires).
    ok = enabled & (blk >= 0) & (slot < b)
    blk_c = jnp.maximum(blk, 0)
    slot_c = jnp.minimum(slot, b - 1)
    st = dict(st)
    st["fill"] = st["fill"].at[blk_c].add(jnp.where(ok, 1, 0))
    st["slot_lba"] = st["slot_lba"].at[blk_c, slot_c].set(
        jnp.where(ok, lba, st["slot_lba"][blk_c, slot_c])
    )
    st["valid"] = st["valid"].at[blk_c, slot_c].set(
        jnp.where(ok, True, st["valid"][blk_c, slot_c])
    )
    st["live"] = st["live"].at[blk_c].add(jnp.where(ok, 1, 0))
    # a FAILED (enabled but not ok) write unmaps the page; a disabled call
    # must leave the mapping untouched
    st["map_blk"] = st["map_blk"].at[lba].set(
        jnp.where(ok, blk, jnp.where(enabled, -1, st["map_blk"][lba]))
    )
    st["map_slot"] = st["map_slot"].at[lba].set(
        jnp.where(ok, slot, jnp.where(enabled, -1, st["map_slot"][lba]))
    )
    st["grp_size"] = st["grp_size"].at[g].add(jnp.where(ok, 1, 0))
    st["n_dropped"] = st["n_dropped"] + jnp.where(
        ok | jnp.logical_not(enabled), 0, 1
    )
    if is_migration:
        st["n_mig"] = st["n_mig"] + jnp.where(ok, 1, 0)
    return st


def _invalidate(st, lba):
    blk = st["map_blk"][lba]
    slot = st["map_slot"][lba]
    has = blk >= 0
    blk_c = jnp.maximum(blk, 0)
    old_g = st["group_of"][blk_c]
    st = dict(st)
    st["valid"] = st["valid"].at[blk_c, slot].set(
        jnp.where(has, False, st["valid"][blk_c, slot])
    )
    st["live"] = st["live"].at[blk_c].add(jnp.where(has, -1, 0))
    st["grp_size"] = st["grp_size"].at[jnp.maximum(old_g, 0)].add(
        jnp.where(has & (old_g >= 0), -1, 0)
    )
    return st, jnp.where(has, old_g, 0)


# ---------------------------------------------------------------------------
# garbage collection (one victim) — §5.4
# ---------------------------------------------------------------------------

def _select_victim(ctx: SimContext, st, g, gc_lru):
    closed = (st["state"] == CLOSED) & (st["group_of"] == g)
    score_lru = jnp.where(closed, st["stamp"], INT_MAX)
    score_greedy = jnp.where(closed, st["live"], INT_MAX)
    victim = jnp.argmin(jnp.where(gc_lru, score_lru, score_greedy))
    # a fully-live greedy victim frees nothing: skip (movement-op no-op guard)
    ok = closed[victim] & (
        gc_lru | (st["live"][victim] < ctx.geom.pages_per_block)
    )
    return victim, ok


def _gc_one(ctx: SimContext, st, g, demote_fn, gc_lru):
    """GC one victim in group g; migrate live pages via the write path.

    demote_fn(st, lba, g) -> target group for a migrated page (§5.6 demotion:
    bloom/fdp detectors may demote during GC; static keeps g).
    """
    victim, ok = _select_victim(ctx, st, g, gc_lru)
    # migrations may need one fresh block beyond the active's free slots:
    # never start a GC with an empty pool (callers keep it ≥ 2).
    ok = ok & (jnp.sum(st["state"] == FREE) >= 1)

    def do(st):
        b = ctx.geom.pages_per_block

        def body(j, st):
            # masked migration (no lax.cond: under vmap a per-slot cond
            # would select over the whole state pytree 16×/GC)
            lba = st["slot_lba"][victim, j]
            is_live = st["valid"][victim, j]
            lba_c = jnp.maximum(lba, 0)  # dead slots hold -1
            st = dict(st)
            st["valid"] = st["valid"].at[victim, j].set(
                jnp.where(is_live, False, st["valid"][victim, j])
            )
            st["live"] = st["live"].at[victim].add(
                jnp.where(is_live, -1, 0)
            )
            g_tgt = demote_fn(st, lba_c, g)  # pure read of st
            st["grp_size"] = st["grp_size"].at[g].add(
                jnp.where(is_live, -1, 0)
            )
            return _write_page(
                ctx, st, lba_c, g_tgt, is_migration=True, enabled=is_live
            )

        st = jax.lax.fori_loop(0, b, body, dict(st))
        # erase
        st["state"] = st["state"].at[victim].set(FREE)
        st["group_of"] = st["group_of"].at[victim].set(-1)
        st["fill"] = st["fill"].at[victim].set(0)
        st["live"] = st["live"].at[victim].set(0)
        st["slot_lba"] = st["slot_lba"].at[victim].set(-1)
        st["valid"] = st["valid"].at[victim].set(False)
        st["stamp"] = st["stamp"].at[victim].set(st["clock"])
        st["clock"] = st["clock"] + 1
        st["grp_phys"] = st["grp_phys"].at[g].add(-1)
        st["n_erase"] = st["n_erase"] + 1
        return st

    return jax.lax.cond(ok, do, lambda s: dict(s), st)


# ---------------------------------------------------------------------------
# over-provisioning allocation (interval) — §5.5
# ---------------------------------------------------------------------------

def _recompute_alloc(ctx: SimContext, st, policy):
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block
    active = st["grp_active"]
    s = jnp.where(active, st["grp_size"].astype(jnp.float32), 0.0)
    s = jnp.maximum(s, jnp.where(active, 1.0, 0.0))
    use_assumed = policy["alloc_mode"] == ALLOC_FDP
    p = jnp.where(
        active, jnp.where(use_assumed, policy["assumed_p"], st["grp_p"]), 0.0
    )
    p = p / jnp.maximum(p.sum(), 1e-9)
    # usable OP = spare pages beyond logical content, minus the GC reserve
    # and one block per active group (absorbs the per-group ceil slack so
    # the budgets can never collectively over-claim the pool)
    n_active = active.sum()
    op_total = (
        jnp.asarray(geom.pba_pages, jnp.float32)
        - (mcfg.gc_reserve_blocks + 1 + n_active) * b
        - s.sum()
    )

    op_closed = allocate_closed_form(
        s, p, op_total,
        cold_rule=True,
        cold_hit_rate_frac=mcfg.cold_hit_rate_frac,
        cold_op_frac=mcfg.cold_op_frac,
    )
    op_size = allocate_by_size(s, op_total)
    op_freq = allocate_by_frequency(p, op_total)
    is_closed = (policy["alloc_mode"] == ALLOC_CLOSED) | use_assumed
    is_freq = policy["alloc_mode"] == ALLOC_FREQ
    op = jnp.where(is_closed, op_closed, jnp.where(is_freq, op_freq, op_size))
    alloc_blocks = jnp.ceil((s + op) / b).astype(jnp.int32)
    alloc_blocks = jnp.where(active, jnp.maximum(alloc_blocks, 1), 0)
    st = dict(st)
    st["grp_alloc"] = alloc_blocks
    return st


def _interval_update(ctx: SimContext, st, policy):
    mcfg = ctx.mcfg
    st = dict(st)
    u = st["grp_writes"].astype(jnp.float32) / ctx.h
    active = st["grp_active"]
    st["grp_p"] = jnp.where(
        active, st["grp_p"] * (1 - mcfg.ewma_a) + mcfg.ewma_a * u, 0.0
    )
    st["grp_writes"] = jnp.zeros_like(st["grp_writes"])
    st["interval"] = st["interval"] + 1
    st["cooldown"] = jnp.maximum(st["cooldown"] - 1, 0)
    st = _maybe_create_or_merge(ctx, st, policy)
    st = _recompute_alloc(ctx, st, policy)
    return st


# ---------------------------------------------------------------------------
# group creation / merging (dynamic mode) — §5.2
# ---------------------------------------------------------------------------

def _hit_rates(st):
    s = jnp.maximum(st["grp_size"].astype(jnp.float32), 1.0)
    hr = st["grp_p"] / s
    return jnp.where(st["grp_active"], hr, -1.0)


def _maybe_create_or_merge(ctx: SimContext, st, policy):
    mcfg = ctx.mcfg
    dynamic = policy["dynamic_groups"]
    f_min = policy["f_min_pages"]
    hr = _hit_rates(st)
    order = jnp.argsort(-hr)  # hottest first
    hottest, second = order[0], order[1]
    n_active = st["grp_active"].sum()
    can_slot = n_active < policy["max_groups"]
    hot_ratio = hr[hottest] / jnp.maximum(hr[second], 1e-12)
    create = (
        dynamic
        & can_slot
        & (st["cooldown"] == 0)
        & (n_active >= 2)
        & (hot_ratio >= mcfg.q_create)
        & (st["grp_size"][hottest] >= f_min)
    )

    def do_create(st):
        st = dict(st)
        slot = jnp.argmin(st["grp_active"])  # first inactive slot
        st["grp_active"] = st["grp_active"].at[slot].set(True)
        # seed stats: half the hottest group's measured frequency
        st["grp_p"] = st["grp_p"].at[slot].set(st["grp_p"][hottest] * 0.5)
        st["grp_size"] = st["grp_size"].at[slot].set(0)
        st["grp_phys"] = st["grp_phys"].at[slot].set(0)
        st["grp_created"] = st["grp_created"].at[slot].set(st["interval"])
        st["cooldown"] = jnp.asarray(mcfg.w_intervals, jnp.int32)
        return st

    st = jax.lax.cond(create, do_create, lambda s: dict(s), st)

    # merge: coldest adjacent pair that converged, or an undersized group
    hr = _hit_rates(st)
    order = jnp.argsort(-hr)
    n_active = st["grp_active"].sum()
    # adjacent pair ratios in hit-rate order
    hr_sorted = hr[order]
    idx = jnp.arange(hr.shape[0])
    valid_pair = (idx + 1 < n_active)
    ratio = hr_sorted / jnp.maximum(jnp.roll(hr_sorted, -1), 1e-12)
    converged = valid_pair & (ratio < 1.3) & (hr_sorted > 0)
    tiny = valid_pair & (
        st["grp_size"][order] < f_min
    ) & (jnp.roll(hr_sorted, -1) > 0)
    mergeable = converged | tiny
    pair_i = jnp.argmax(mergeable)
    do_merge = (
        dynamic & mergeable[pair_i] & (st["cooldown"] == 0) & (n_active > 2)
    )

    def merge(st):
        st = dict(st)
        g_from = order[pair_i]          # hotter of the pair
        g_to = order[pair_i + 1]        # absorbed into the colder
        # relabel blocks (the paper: a merge is logical)
        st["group_of"] = jnp.where(
            st["group_of"] == g_from, g_to, st["group_of"]
        )
        # seal g_from's active block (no longer reachable)
        ab = st["active_blk"][g_from]
        st["state"] = st["state"].at[jnp.maximum(ab, 0)].set(
            jnp.where(ab >= 0, CLOSED, st["state"][jnp.maximum(ab, 0)])
        )
        st["active_blk"] = st["active_blk"].at[g_from].set(-1)
        st["grp_size"] = st["grp_size"].at[g_to].add(st["grp_size"][g_from])
        st["grp_phys"] = st["grp_phys"].at[g_to].add(st["grp_phys"][g_from])
        st["grp_p"] = st["grp_p"].at[g_to].add(st["grp_p"][g_from])
        st["grp_writes"] = st["grp_writes"].at[g_to].add(st["grp_writes"][g_from])
        for key in ("grp_size", "grp_phys", "grp_p", "grp_writes"):
            st[key] = st[key].at[g_from].set(0)
        st["grp_active"] = st["grp_active"].at[g_from].set(False)
        st["cooldown"] = jnp.asarray(mcfg.w_intervals, jnp.int32)
        return st

    return jax.lax.cond(do_merge, merge, lambda s: dict(s), st)


# ---------------------------------------------------------------------------
# temperature detection — §5.6 (+ oracle modes for §6 experiments)
# ---------------------------------------------------------------------------

def _sgv_neighbors(st):
    """hotter_of[g], colder_of[g] by current hit-rate order."""
    hr = _hit_rates(st)
    g_max = hr.shape[0]
    # rank[g] = position in descending order
    order = jnp.argsort(-hr)
    rank = jnp.zeros(g_max, jnp.int32).at[order].set(jnp.arange(g_max))
    n_active = st["grp_active"].sum()

    def neighbor(g, delta):
        r = rank[g] + delta
        r = jnp.clip(r, 0, n_active - 1)
        return order[r]

    return neighbor


def _target_group_app(ctx: SimContext, st, lba, cur_g, policy, rate_fn):
    """Target group for an application update of `lba` living in cur_g."""
    cur_g = jnp.asarray(cur_g, jnp.int32)

    def static_br(st):
        return dict(st), cur_g

    def fdp_br(st):
        # fixed assumed per-page rate bands: promote if ≥2× the group's
        # assumed rate (paper §5/§6: FDP's fixed-order assumption)
        neighbor = _sgv_neighbors(st)
        r = rate_fn(st, lba)
        promote = r > 2.0 * policy["fdp_rate"][cur_g]
        g = jnp.where(promote, neighbor(cur_g, -1), cur_g)
        return dict(st), g.astype(jnp.int32)

    def bloom_br(st):
        # bloom (§5.6): in both filters → promote
        st, in_both = _bloom_update(ctx, st, lba, cur_g)
        g = jnp.where(in_both, _sgv_neighbors(st)(cur_g, -1), cur_g)
        return st, g.astype(jnp.int32)

    branches = [static_br, fdp_br]
    if ctx.use_bloom:
        branches.append(bloom_br)
    return jax.lax.switch(policy["td_mode"], branches, dict(st))


def _target_group_gc(ctx: SimContext, st, lba, cur_g, policy, rate_fn):
    cur_g = jnp.asarray(cur_g, jnp.int32)

    def static_br(st):
        return cur_g

    def fdp_br(st):
        neighbor = _sgv_neighbors(st)
        r = rate_fn(st, lba)
        demote = r < 0.5 * policy["fdp_rate"][cur_g]
        return jnp.where(demote, neighbor(cur_g, +1), cur_g).astype(jnp.int32)

    def bloom_br(st):
        # bloom: in neither filter during a migration → demote
        neighbor = _sgv_neighbors(st)
        in_active = _bloom_query(ctx, st["bloom_active"], lba, cur_g)
        in_passive = _bloom_query(ctx, st["bloom_passive"], lba, cur_g)
        g = jnp.where(~in_active & ~in_passive, neighbor(cur_g, +1), cur_g)
        return g.astype(jnp.int32)

    branches = [static_br, fdp_br]
    if ctx.use_bloom:
        branches.append(bloom_br)
    return jax.lax.switch(policy["td_mode"], branches, dict(st))


# -- bloom filter pair (per group) ------------------------------------------

def _bloom_hashes(ctx: SimContext, lba):
    bits = bloom_bits(ctx.geom, ctx.mcfg)
    u = lba.astype(jnp.uint32)
    h1 = (u * jnp.uint32(2654435761)) % jnp.uint32(bits)
    h2 = (u * jnp.uint32(40503) + jnp.uint32(99991)) % jnp.uint32(bits)
    return h1.astype(jnp.int32), h2.astype(jnp.int32), bits


def _bloom_query(ctx, filt, lba, g):
    h1, h2, _ = _bloom_hashes(ctx, lba)
    return filt[g, h1] & filt[g, h2]


def _bloom_update(ctx: SimContext, st, lba, g):
    """Insert lba into group g's active filter; rotate when the group's
    write interval (= group size) elapses. Returns (st, was_in_both)."""
    h1, h2, _ = _bloom_hashes(ctx, lba)
    in_active = st["bloom_active"][g, h1] & st["bloom_active"][g, h2]
    in_passive = st["bloom_passive"][g, h1] & st["bloom_passive"][g, h2]
    st = dict(st)
    st["bloom_active"] = (
        st["bloom_active"].at[g, h1].set(True).at[g, h2].set(True)
    )
    st["bloom_writes"] = st["bloom_writes"].at[g].add(1)
    rotate = st["bloom_writes"][g] >= jnp.maximum(st["grp_size"][g], 64)
    # row-masked rotation (no lax.cond: under vmap a cond would select over
    # the full [G, bits] filter pair every step; this touches one row)
    row_active = st["bloom_active"][g]
    st["bloom_passive"] = st["bloom_passive"].at[g].set(
        jnp.where(rotate, row_active, st["bloom_passive"][g])
    )
    st["bloom_active"] = st["bloom_active"].at[g].set(
        jnp.where(rotate, False, row_active)
    )
    st["bloom_writes"] = st["bloom_writes"].at[g].set(
        jnp.where(rotate, 0, st["bloom_writes"][g])
    )
    return st, in_active & in_passive


# ---------------------------------------------------------------------------
# the step + runner
# ---------------------------------------------------------------------------

def make_step(ctx: SimContext, policy, rate_fn):
    """Build the per-write scan step.

    policy: traced pytree from :func:`policy_from_config` (per-drive under
    vmap). rate_fn(st, lba, t) -> true per-page update rate of `lba` at
    global write index t (oracle detector input; phase-aware in fleets).
    Scan input = (lba, t); t is the global application-write index, which is
    deliberately NOT taken from batched state so the interval predicate
    stays a scalar under vmap (the expensive §5.1 bookkeeping then lowers
    to a real branch taken every h steps, not a per-step select).
    """
    geom, mcfg = ctx.geom, ctx.mcfg
    b = geom.pages_per_block

    def step(st, xs):
        lba, t = xs

        def lookup(s, l):
            return rate_fn(s, l, t)

        def demote_fn(s, l, g):
            return _target_group_gc(ctx, s, l, g, policy, lookup)

        st, old_g = _invalidate(st, lba)
        st, g = _target_group_app(ctx, st, lba, old_g, policy, lookup)
        g = jnp.where(st["grp_active"][g], g, old_g)

        # GC when the group needs a new block it is not entitled to, or the
        # pool is at reserve.
        blk = st["active_blk"][g]
        needs_block = jnp.where(
            blk >= 0, st["fill"][jnp.maximum(blk, 0)] >= b, True
        )
        free_blocks = jnp.sum(st["state"] == FREE)
        over_budget = st["grp_phys"][g] >= st["grp_alloc"][g]
        low_pool = free_blocks <= mcfg.gc_reserve_blocks
        do_gc = needs_block & (over_budget | low_pool)
        st = jax.lax.cond(
            do_gc,
            lambda s: _gc_one(ctx, s, g, demote_fn, policy["gc_lru"]),
            lambda s: dict(s),
            st,
        )

        # emergency valve: if the pool is (nearly) empty, greedily reclaim
        # from the fullest group until headroom returns (bounded loop; only
        # fires when a policy briefly overdraws its budget).
        def needs_air(carry):
            s, tries = carry
            return (jnp.sum(s["state"] == FREE) < 2) & (tries < 4)

        def reclaim(carry):
            s, tries = carry
            # global greedy: the best victim anywhere (its group pays)
            closed = s["state"] == CLOSED
            score = jnp.where(closed, s["live"], INT_MAX)
            victim = jnp.argmin(score)
            g_v = jnp.maximum(s["group_of"][victim], 0)
            return (
                _gc_one(ctx, s, g_v, demote_fn, jnp.asarray(False)),
                tries + 1,
            )

        st, _ = jax.lax.while_loop(needs_air, reclaim, (st, 0))

        st = _write_page(ctx, st, lba, g, is_migration=False)
        st["n_app"] = st["n_app"] + 1
        st["grp_writes"] = st["grp_writes"].at[g].add(1)

        # movement operations (§5.3): one compaction GC per step on the most
        # surplus group, donating the redeemed block to the pool.
        surplus = jnp.where(
            st["grp_active"], st["grp_phys"] - st["grp_alloc"], -INT_MAX
        )
        g_s = jnp.argmax(surplus)
        pool_ok = jnp.sum(st["state"] == FREE) >= 2  # migration headroom
        st = jax.lax.cond(
            policy["movement_ops"] & (surplus[g_s] >= 1) & pool_ok,
            lambda s: _gc_one(ctx, s, g_s, demote_fn, policy["gc_lru"]),
            lambda s: dict(s),
            st,
        )

        # interval completion (§5.1); t+1 == n_app after this write, so the
        # predicate is exactly the pre-refactor (n_app % h == 0) — but as a
        # scalar, shared by every drive of a vmapped fleet.
        is_interval = ((t + 1) % ctx.h) == 0
        st = jax.lax.cond(
            is_interval,
            lambda s: _interval_update(ctx, s, policy),
            lambda s: dict(s),
            st,
        )
        return st, (st["n_app"], st["n_mig"])

    return step


@functools.partial(jax.jit, static_argnames=("ctx",))
def _run_jit(ctx: SimContext, st, lbas, page_rate, policy):
    def rate_fn(s, lba, t):
        return page_rate[lba]

    step = make_step(ctx, policy, rate_fn)
    ts = st["n_app"] + jnp.arange(lbas.shape[0], dtype=jnp.int32)
    return jax.lax.scan(step, st, (lbas, ts))


def run(ctx: SimContext, st, lbas, *, page_rate=None, assumed_p=None, fdp_rate=None):
    """Run the simulator over a segment of writes.

    lbas: int32 [T]; page_rate: float32 [LBA] true per-page update rates
    (oracle detector modes). Returns (final_state, trace dict of CUMULATIVE
    counters [T]) — segment the workload (e.g. at a frequency swap) by
    calling run() repeatedly with updated oracle arrays.
    """
    lbas = jnp.asarray(lbas, jnp.int32)
    if page_rate is None:
        page_rate = jnp.zeros(ctx.geom.lba_pages, jnp.float32)
    policy = policy_from_config(ctx, assumed_p, fdp_rate)
    st, (app, mig) = _run_jit(
        ctx, st, lbas, jnp.asarray(page_rate, jnp.float32), policy
    )
    return st, {"app": app, "mig": mig}


