"""Core: the paper's contribution — WA analytics, OP allocation, SSD simulator,
and the Wolf / FDP / single-group block managers."""

from .analytics import (
    block_decay_updates,
    block_live_pages,
    delta_from_op_ratio,
    delta_from_op_ratio_lambertw,
    delta_from_wa,
    lambertw0,
    op_ratio_from_delta,
    op_ratio_from_wa,
    wa_from_delta,
    wa_from_op_ratio,
)
from .allocation import (
    allocate_by_frequency,
    allocate_by_size,
    allocate_closed_form,
    group_delta,
    group_wa,
    hillclimb_allocation,
    optimal_allocation,
    total_wa,
)
