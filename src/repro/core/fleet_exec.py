"""Fleet execution backend: shard_map over a 1-D drive-axis mesh.

This module owns everything between ``simulate_fleet``'s per-sub-batch
arrays and the devices:

* **Sharding** — each sub-batch runs as ``jit(shard_map(vmap(run_one)))``
  over :func:`repro.launch.mesh.drive_mesh`'s ``"drives"`` axis. Drives are
  independent lanes, so each device executes the plain vmapped scan over
  its slice of the batch; numerics are bit-identical to the single-device
  ``jit(vmap(...))`` path (which remains the ``n_dev == 1`` special case —
  no mesh, no collective, same trace). The legacy ``pmap(vmap(...))``
  executor is gone: shard_map composes with jit (one dispatch, donation,
  the compilation cache) where pmap was its own retired code path.

* **Padding** — a sub-batch whose size is not a multiple of the device
  count is padded with inert filler drives (copies of the sub-batch's
  drive 0) up to the next multiple, and the filler rows are dropped before
  results surface. Wall-clock per device is ``ceil(B / n_dev)`` drive
  scans, so the pad fills lanes that would otherwise sit idle — padding is
  never slower than shrinking the shard count, which is why the old
  "largest divisor of B" clamp (which silently collapsed ragged sub-batches
  to 1 device) is gone.

* **Donation** — the stacked drive state (the dominant buffer,
  O(fleet size) many block/page arrays) is donated into the jitted scan,
  so the executor holds one copy per sub-batch in flight instead of
  input + output: peak state memory stays O(B/n_dev) per device. On
  backends without input-output aliasing (older XLA:CPU) donation is a
  silent no-op — correctness is unaffected either way because callers
  never reuse the stacked input.

* **Compiled-step cache** — runners are memoized on
  ``(SimContext, scan length, sampler kind, n_dev)``. The SimContext of a
  sub-batch is a pure function of its ``_part_key`` (plus geometry and the
  fleet-shared constants), so sweep grids that revisit a step structure —
  e.g. the same policy grid at a new seed set, or bench scaling curves —
  reuse the jitted runner and pay XLA compilation once per structure.
  :func:`step_cache_stats` exposes hit/miss counters (asserted by
  tests/test_fleet_mesh.py); :func:`enable_persistent_compilation_cache`
  additionally wires jax's on-disk compilation cache so the once-per-
  structure cost survives process restarts. The on-disk layer is STRICTLY
  opt-in (set ``REPRO_JAX_CACHE_DIR`` or call it explicitly) and carries a
  hazard note — see the function docstring.
"""

from __future__ import annotations

import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.core.simulator import SimContext, make_step, scan_writes
from repro.core.workloads import sample_phases_device
from repro.launch.mesh import drive_mesh
from repro.utils.hostdev import host_device_flag


@dataclasses.dataclass
class SubbatchFailure:
    """One failed sub-batch resolution (see SubbatchResolutionError)."""

    subbatch: int            # dispatch-order index of the sub-batch
    part_key: tuple          # its fleet._part_key (step structure)
    drive_ids: tuple         # original spec indices of its drives
    labels: tuple            # DriveSpec.label per drive
    error: Exception         # the underlying exception, unchanged

    def __str__(self) -> str:
        return (
            f"sub-batch {self.subbatch} (part_key={self.part_key}, "
            f"drives={list(self.drive_ids)}, labels={list(self.labels)}): "
            f"{type(self.error).__name__}: {self.error}"
        )


class SubbatchResolutionError(RuntimeError):
    """Raised by ``simulate_fleet`` when one or more sub-batches failed to
    resolve. Dispatch is asynchronous, so a device-side error (OOM, a
    poisoned buffer, a runtime failure) only surfaces when the host blocks
    on the outputs — this wrapper pins each failure to its sub-batch
    index, ``_part_key``, and drive ids, and is raised only AFTER every
    healthy sub-batch has resolved (their work is never orphaned; the
    partial results are simply not returned). ``failures`` holds one
    :class:`SubbatchFailure` per failed sub-batch."""

    def __init__(self, failures: list[SubbatchFailure], *,
                 n_subbatches: int):
        self.failures = list(failures)
        self.n_subbatches = n_subbatches
        detail = "\n  ".join(str(f) for f in self.failures)
        super().__init__(
            f"{len(self.failures)}/{n_subbatches} fleet sub-batches failed "
            f"to resolve:\n  {detail}"
        )


def resolve_devices(devices: int | str | None) -> int:
    """Resolve ``simulate_fleet``'s ``devices=`` argument to a device count.

    None/1 = single device; ``"auto"`` = every visible jax device; an int
    (or numeric string) is clamped to the visible device count. On CPU the
    visible count is an IMPORT-ORDER property — jax locks it at first
    backend init, so ``"auto"`` from an entry point that imported jax
    before setting ``--xla_force_host_platform_device_count`` sees 1
    device. Call :func:`repro.utils.hostdev.force_host_device_count`
    before the first jax import; this resolver warns about the trap rather
    than silently degrading.
    """
    if devices in (None, 1):
        return 1
    n_avail = len(jax.devices())
    if devices == "auto":
        if n_avail == 1 and jax.default_backend() == "cpu" \
                and host_device_flag() is None:
            warnings.warn(
                'devices="auto" sees a single CPU device: jax was '
                "initialized before --xla_force_host_platform_device_count "
                "was set. Call repro.utils.hostdev.force_host_device_count "
                "before the first jax import to shard across cores.",
                RuntimeWarning,
                stacklevel=3,
            )
        return n_avail
    return max(1, min(int(devices), n_avail))


def pad_batch(tree, pad: int):
    """Append ``pad`` filler rows to every leaf's leading (drive) axis by
    replicating row 0. Lanes are independent, so fillers change nothing for
    real drives; callers drop the filler rows from every output."""
    if pad == 0:
        return tree
    return jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])], axis=0
        ),
        tree,
    )


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0


_RUNNERS: dict[tuple, object] = {}
_STATS = CacheStats()


def step_cache_stats() -> CacheStats:
    """In-process compiled-runner memo counters (a copy)."""
    return dataclasses.replace(_STATS)


def step_cache_clear() -> None:
    """Drop the runner memo and zero the counters (tests only — the
    underlying jax jit caches are left alone)."""
    _RUNNERS.clear()
    _STATS.hits = 0
    _STATS.misses = 0


_PERSISTENT_WIRED = False

# jaxlib builds whose XLA:CPU executable serialization corrupts the heap
# when the Pallas-bearing step executables are written to the on-disk
# cache (bisected on 0.4.37; 0.4.36 ships the same serialization path).
# See the hazard note on enable_persistent_compilation_cache.
_CACHE_BAD_JAXLIB_CPU = ("0.4.36", "0.4.37")


def _persistent_cache_hazard() -> str | None:
    """Return a reason string when the running jaxlib/backend combo is
    known to corrupt the heap with the on-disk cache enabled, else None."""
    try:
        import jaxlib
        jaxlib_version = jaxlib.__version__
    except Exception:  # pragma: no cover — jaxlib always ships with jax
        jaxlib_version = jax.__version__
    if (
        jax.default_backend() == "cpu"
        and jaxlib_version in _CACHE_BAD_JAXLIB_CPU
    ):
        return (
            f"jaxlib {jaxlib_version} on XLA:CPU corrupts the process heap "
            "when serializing Pallas-bearing step executables "
            "(malloc_consolidate/segfault after ~a dozen cached compiles)"
        )
    return None


def enable_persistent_compilation_cache(path: str | None = None) -> str:
    """Wire jax's on-disk compilation cache (idempotent).

    The in-process memo deduplicates compiles within a run; this extends
    the once-per-step-structure guarantee across processes — a sweep
    driver that restarts per grid, or repeated bench runs, reload the XLA
    executable instead of recompiling. Default location
    ``$REPRO_JAX_CACHE_DIR`` or ``~/.cache/repro_jax_cache``.

    .. warning::
        Opt-in for a reason: on jaxlib 0.4.37's XLA:CPU backend,
        serializing the Pallas-kernel-bearing step executables corrupts
        the process heap — after roughly a dozen cached compiles the
        process dies with ``malloc_consolidate()`` / segfault. Bisected:
        a plain-jax scan caches fine under the same config; any mix of
        this module's runners and the per-drive step jits crashes once
        enough executables are written, with or without donation and on
        both CPU runtimes (thunk and legacy). Nothing in the repo enables
        this by default, and since the fault-robustness pass this note is
        ENFORCED: on a known-bad jaxlib/backend combo
        (:data:`_CACHE_BAD_JAXLIB_CPU` × XLA:CPU) the call warns and
        refuses to wire the cache instead of arming a delayed crash. Set
        ``REPRO_JAX_CACHE_FORCE=1`` to override on a build you have
        re-validated (a full bench run survives with the cache on).
    """
    global _PERSISTENT_WIRED
    path = path or os.environ.get(
        "REPRO_JAX_CACHE_DIR", os.path.expanduser("~/.cache/repro_jax_cache")
    )
    if _PERSISTENT_WIRED:
        return path
    hazard = _persistent_cache_hazard()
    if hazard and not os.environ.get("REPRO_JAX_CACHE_FORCE"):
        warnings.warn(
            f"refusing to enable the on-disk compilation cache: {hazard}. "
            "Set REPRO_JAX_CACHE_FORCE=1 to override on a re-validated "
            "build.",
            RuntimeWarning,
            stacklevel=2,
        )
        return path
    jax.config.update("jax_compilation_cache_dir", path)
    # sim steps compile in O(seconds); cache anything non-trivial
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    _PERSISTENT_WIRED = True
    return path


def subbatch_runner(ctx: SimContext, n_total: int, on_device_sampler: bool,
                    n_dev: int):
    """Compiled runner for one sub-batch, memoized on its step structure.

    The returned callable maps stacked per-drive args (leading axis B,
    a multiple of ``n_dev``) to ``(final_state, (app, mig), lbas)``. The
    state argument is donated. Dispatch is asynchronous: the call returns
    as soon as the computation is enqueued, so the host can build the next
    sub-batch while this one executes (simulate_fleet's pipeline).
    """
    key = (ctx, n_total, on_device_sampler, n_dev)
    fn = _RUNNERS.get(key)
    if fn is not None:
        _STATS.hits += 1
        return fn
    _STATS.misses += 1

    def run_one(st, stream, params, page_rate, page_group0, policy):
        ops = None
        if on_device_sampler:
            if ctx.with_trim:
                ops, lbas = sample_phases_device(
                    stream, params, n_total, with_ops=True
                )
            else:
                lbas = sample_phases_device(stream, params, n_total)
        elif ctx.with_trim:
            ops, lbas = stream
        else:
            lbas = stream
        cum = jnp.cumsum(params["counts"])

        def rate_fn(s, lba, t):
            # t is the shared EVENT clock (== write clock for pure-write
            # sub-batches); phase boundaries are event counts either way
            ph = jnp.minimum(
                jnp.searchsorted(cum, t, side="right"), cum.shape[0] - 1
            )
            return page_rate[ph, lba]

        step = make_step(ctx, policy, rate_fn, page_group0)
        ts = jnp.arange(n_total, dtype=jnp.int32)
        st, trace = scan_writes(ctx, step, st, lbas, ts, ops)
        return st, trace, lbas

    batched = jax.vmap(run_one)
    if n_dev > 1:
        spec = PartitionSpec("drives")
        # check_rep=False: the replication checker has no rule for
        # lax.while_loop (the GC/valve drains) in this jax version; the
        # body is collective-free with fully partitioned in/outs, so the
        # check is vacuous here anyway.
        batched = shard_map(
            batched, mesh=drive_mesh(n_dev), in_specs=spec, out_specs=spec,
            check_rep=False,
        )
    fn = jax.jit(batched, donate_argnums=(0,))
    _RUNNERS[key] = fn
    return fn
