"""Quickstart: the paper's model + Wolf in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    allocate_closed_form,
    delta_from_op_ratio,
    optimal_allocation,
    total_wa,
    wa_from_op_ratio,
)
from repro.core import managers as M
from repro.core import workloads as W
from repro.core.ssd import Geometry

print("=== 1. The closed-form WA model (paper §4) ===")
for r in (0.6, 0.7, 0.8, 0.9):
    print(
        f"  LBA/PBA={r:.2f}  δ={float(delta_from_op_ratio(jnp.asarray(r))):.3f}"
        f"  WA={float(wa_from_op_ratio(jnp.asarray(r))):.2f}"
    )

print("\n=== 2. Near-optimal OP allocation (paper §5.5, eq. 8) ===")
s = jnp.asarray([50_000.0, 30_000.0, 20_000.0])  # group sizes (pages)
p = jnp.asarray([0.1, 0.3, 0.6])                  # update frequencies
op = 40_000.0                                      # spare pages
cf = allocate_closed_form(s, p, op)
opt = optimal_allocation(s, p, jnp.asarray(op))
print(f"  closed form: {np.asarray(cf).round(0)}  WA={float(total_wa(s,p,cf)):.4f}")
print(f"  optimum:     {np.asarray(opt).round(0)}  WA={float(total_wa(s,p,opt)):.4f}")

print("\n=== 3. Wolf vs FDP across a workload swap (paper §6.1) ===")
geom = Geometry(n_luns=4, blocks_per_lun=48, pages_per_block=16)
ph1, ph2 = W.swap_phases(geom.lba_pages, 40_000, p=(0.1, 0.9))
for name, mcfg in (("wolf", M.wolf()), ("fdp", M.fdp())):
    swap = M.simulate(geom, mcfg, [ph1, ph2], seed=0)
    noswap = M.simulate(geom, mcfg, [ph1, ph1], seed=0)
    extra = float(swap.mig[-1] - noswap.mig[-1]) / geom.pba_pages
    print(f"  {name:5s}: WA={swap.wa_total:.3f}  extra migrations/PBA={extra:+.3f}")

print("\nSee examples/ssd_experiment.py, train_lm.py, serve_wolf_kv.py for more.")
