"""End-to-end driver for the paper's own experiment kind: a configurable
SSD simulation campaign (the storage-paper analogue of a training run).

    PYTHONPATH=src python examples/ssd_experiment.py --workload swap \
        --managers wolf,fdp,single --writes 100000
"""

import argparse

import numpy as np

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.ssd import Geometry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("uniform", "swap", "tpcc", "exp5"),
                    default="swap")
    ap.add_argument("--managers", default="wolf,fdp")
    ap.add_argument("--writes", type=int, default=100_000)
    ap.add_argument("--lba-pba", type=float, default=0.7)
    ap.add_argument("--blocks-per-lun", type=int, default=64)
    ap.add_argument("--pages-per-block", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    geom = Geometry(
        blocks_per_lun=args.blocks_per_lun,
        pages_per_block=args.pages_per_block,
        lba_pba=args.lba_pba,
    )
    lba = geom.lba_pages
    if args.workload == "uniform":
        phases = [W.uniform(lba, args.writes)]
    elif args.workload == "swap":
        phases = list(W.swap_phases(lba, args.writes))
    elif args.workload == "exp5":
        base = W.exponential_groups(lba, args.writes)
        phases = [base, W.pairwise_swap(base, 0, 4, args.writes)]
    else:
        phases = [W.tpcc_like(lba, args.writes)]

    presets = {
        "wolf": M.wolf, "fdp": M.fdp, "single": M.single_group,
        "wolf_lru": M.wolf_lru, "wolf_dynamic": M.wolf_dynamic,
        "wolf_endurance": M.wolf_endurance,
    }
    print(f"SSD: {geom.n_blocks} blocks × {geom.pages_per_block} pages, "
          f"LBA/PBA={geom.lba_pba}  workload={args.workload}")
    for name in args.managers.split(","):
        res = M.simulate(geom, presets[name](), phases, seed=args.seed)
        curve = res.wa_curve(max(2000, args.writes // 20))
        spark = " ".join(f"{x:.2f}" for x in curve[:: max(1, len(curve) // 12)])
        print(f"  {name:12s} WA={res.wa_total:.3f}   over time: {spark}")


if __name__ == "__main__":
    main()
