"""Sweep a policy × workload grid as ONE batched fleet simulation.

The batched analogue of examples/ssd_experiment.py: instead of looping
``managers.simulate`` over configurations, every (manager, workload, seed)
combination becomes a drive of a single jitted vmap(lax.scan) — write
streams are sampled on device, and the grid's WA landscape comes back in
one call. Alongside each simulated WA the closed-form model prediction
(paper eq. 3/5, evaluated at the drive's final operating point) is
reported with its relative error — model-vs-simulation across the whole
grid in one pass.

The grid carries a TRIM axis: utilization-sweep drives hold a fraction
t of the logical span trimmed at steady state (op-stream engine), and the
report prints simulated WA against the Frankie effective-OP prediction
``wa_from_op_ratio(effective_op_ratio(r, t))`` — trimmed space is dynamic
over-provisioning, so WA falls with t along the model curve.

A final wear sweep runs (α, β, γ, τ) victim-score weight points — greedy,
two wear-leveling strengths, LRU — as one more fleet grid and reports each
point's erase-count variance, max/mean P-E imbalance, and DWPD projection
next to its WA: the endurance-vs-WA trade-off in a single compiled call.

    PYTHONPATH=src python examples/fleet_sweep.py --writes 20000 --seeds 2
"""

import argparse

import numpy as np

from repro.core import analytics as A
from repro.core import managers as M
from repro.core import workloads as W
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.ssd import Geometry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--writes", type=int, default=20_000)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--lba-pba", type=float, default=0.7)
    ap.add_argument("--devices", default=None,
                    help='"auto" to shard across jax.devices()')
    args = ap.parse_args()

    geom = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8,
                    lba_pba=args.lba_pba)
    lba = geom.lba_pages
    managers = (("wolf", M.wolf), ("fdp", M.fdp), ("single", M.single_group))
    workloads = (
        ("two_modal", lambda: (W.two_modal(lba, args.writes),)),
        ("swap", lambda: tuple(W.swap_phases(lba, args.writes // 2))),
        ("tpcc", lambda: (W.tpcc_like(lba, args.writes),)),
    )
    specs = [
        DriveSpec(mk(), wl(), seed=seed, name=f"{mn}/{wn}#{seed}")
        for seed in range(args.seeds)
        for mn, mk in managers
        for wn, wl in workloads
    ]
    fleet = simulate_fleet(geom, specs, sampler="jax", devices=args.devices)

    print(f"{len(specs)} drives × {args.writes} writes "
          f"(geometry: {geom.n_blocks} blocks, LBA/PBA {geom.lba_pba})\n")
    window = max(args.writes // 10, 1000)
    predicted = fleet.predicted_wa()
    rel_err = fleet.model_error(window=window, pred=predicted)
    width = max(len(s.name) for s in specs)
    for i, s in enumerate(specs):
        curve = fleet.result(i).wa_curve(window)
        print(f"{s.name.ljust(width)}  WA_total={fleet.wa_total[i]:6.3f}  "
              f"WA_eq={np.mean(curve[-3:]):6.3f}  "
              f"WA_model={predicted[i]:6.3f}  err={rel_err[i]:+7.1%}")
    print(f"\nmodel vs simulation (eq. 3/5) across the grid: "
          f"mean |rel err| = {np.mean(np.abs(rel_err)):.1%}, "
          f"worst = {np.max(np.abs(rel_err)):.1%}")
    # the paper's bottom line, read off the grid: wolf ≤ fdp per workload
    for wn, _ in workloads:
        wa = {
            mn: np.mean([fleet.wa_total[i] for i, s in enumerate(specs)
                         if s.name.startswith(f"{mn}/{wn}")])
            for mn, _ in managers
        }
        print(f"\n{wn}: " + "  ".join(f"{k}={v:.3f}" for k, v in wa.items()))

    # -- TRIM sweep: utilization × trim-rate in one op-stream fleet ---------
    # Frankie et al.: trimmed space is dynamic OP, so the LRU single-group
    # drive should track wa_from_op_ratio(effective_op_ratio(r, t)).
    trim_fracs = (0.0, 0.1, 0.25, 0.5)
    import dataclasses
    mcfg = dataclasses.replace(M.single_group(), gc_policy="lru")
    trim_specs = [
        DriveSpec(mcfg, (W.trimmed(W.uniform(lba, args.writes), t),),
                  seed=11, name=f"single-lru/trim={t}")
        for t in trim_fracs
    ]
    trim_fleet = simulate_fleet(geom, trim_specs, sampler="jax",
                                devices=args.devices)
    # reserve-adjusted base utilization, as in the Fig.-1 equilibrium test
    ppb = geom.pages_per_block
    usable = geom.pba_pages - 3 * ppb
    print("\nTRIM sweep (single-group LRU, Frankie effective-OP model):")
    errs = []
    for i, t in enumerate(trim_fracs):
        t_meas = trim_fleet.trim_fraction()[i]
        wa_sim = float(np.mean(trim_fleet.result(i).wa_curve(window)[-3:]))
        wa_model = float(A.wa_from_op_ratio(
            A.effective_op_ratio(geom.lba_pages / usable, t_meas)
        ))
        errs.append((wa_sim - wa_model) / wa_model)
        print(f"  t={t:4.2f} (measured {t_meas:5.3f})  WA_sim={wa_sim:6.3f}  "
              f"WA_model={wa_model:6.3f}  err={errs[-1]:+7.1%}")
    print(f"trim-sweep model vs simulation: mean |rel err| = "
          f"{np.mean(np.abs(errs)):.1%}, worst = {np.max(np.abs(errs)):.1%}")

    # -- wear weight sweep: (α, β, γ, τ) victim-score points in ONE grid ----
    # GC policy is a traced weight vector, so the endurance/WA trade-off is
    # a single fleet call: greedy is (1,0,0,0) and the wear points add
    # β·erase_count pressure to the same score. Endurance read-outs come
    # straight off the carried erase aggregates — no extra reduction.
    skew = (W.two_modal(lba, args.writes, p_hot=0.9, frac_hot=0.2),)
    points = [
        ("greedy     (β=0)   ", M.wolf()),
        ("wear       (β=0.25)", M.wolf_wear()),
        ("wear-heavy (β=1.0) ", dataclasses.replace(
            M.wolf_wear(), gc_beta=1.0)),
        ("lru        (γ=1)   ", M.wolf_lru()),
    ]
    wear_specs = [
        DriveSpec(mcfg, skew, seed=7, name=nm.split()[0])
        for nm, mcfg in points
    ]
    wear_fleet = simulate_fleet(geom, wear_specs, sampler="jax",
                                devices=args.devices)
    wvar = wear_fleet.wear_variance()
    wimb = wear_fleet.wear_imbalance()
    dwpd = wear_fleet.lifetime_dwpd()
    print("\nwear weight sweep (skewed two_modal, p_hot=0.9/frac_hot=0.2):")
    for i, (nm, _) in enumerate(points):
        print(f"  {nm}  WA={wear_fleet.wa_total[i]:6.3f}  "
              f"Var[P-E]={wvar[i]:8.2f}  max/mean={wimb[i]:5.2f}  "
              f"DWPD@3k={dwpd[i]:6.2f}")
    var_ratio = wvar[0] / max(wvar[1], 1e-9)
    wa_delta = wear_fleet.wa_total[1] / wear_fleet.wa_total[0] - 1.0
    print(f"wear (β=0.25) vs greedy: erase-variance ÷{var_ratio:.1f} "
          f"for WA {wa_delta:+.1%} — leveling is not free, but cheap")
    # larger β overshoots: GC starts cleaning full cold blocks, churning
    # erases, so the variance win SHRINKS while the WA tax grows
    assert var_ratio >= 2.0, (
        f"wear point should level >=2x vs greedy, got {var_ratio:.2f}"
    )


if __name__ == "__main__":
    main()
