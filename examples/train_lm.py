"""Train a small LM for a few hundred steps with the full production stack:
AdamW + microbatching + checkpointing + the fault-tolerant runner.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="internlm2-1.8b")
    args = ap.parse_args()
    sys.exit(train_main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", "--microbatches", "2",
        "--checkpoint-every", "100",
    ]))
