"""Serve a small model with batched requests over the Wolf-KV paged cache —
the paper's block manager as a first-class serving feature.

    PYTHONPATH=src python examples/serve_wolf_kv.py --requests 9
"""

import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.exit(serve_main(sys.argv[1:]))
