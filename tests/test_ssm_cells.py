"""Recurrent-cell correctness: chunkwise-parallel forms vs sequential
oracles (the TPU-native forms must match the exact recurrences)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import ssm


def _x(seed, b, s, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, s, d)) * 0.5


class TestMLSTM:
    @pytest.mark.parametrize("chunk", [8, 16, 64])
    @pytest.mark.parametrize("s", [32, 48, 128])
    def test_chunked_matches_sequential(self, chunk, s):
        d, h, dh = 64, 4, 16
        params = ssm.mlstm_init(jax.random.PRNGKey(0), d, h, dh, jnp.float32)
        x = _x(1, 2, s, d)
        seq = ssm.mlstm_sequential(params, x)
        par, _ = ssm.mlstm_chunked(params, x, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(par), np.asarray(seq), atol=2e-4, rtol=2e-3
        )

    def test_decode_matches_sequential(self):
        d, h, dh, s = 64, 4, 16, 24
        params = ssm.mlstm_init(jax.random.PRNGKey(0), d, h, dh, jnp.float32)
        x = _x(2, 1, s, d)
        seq = ssm.mlstm_sequential(params, x)
        state = ssm.mlstm_init_state_raw(1, h, dh)
        outs = []
        for t in range(s):
            y, state = ssm.mlstm_decode_step(params, state, x[:, t])
            outs.append(y)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec), np.asarray(seq), atol=2e-4, rtol=2e-3
        )

    def test_chunked_state_carry(self):
        """Processing [a; b] in one call == prefix then continue with state."""
        d, h, dh = 64, 4, 16
        params = ssm.mlstm_init(jax.random.PRNGKey(3), d, h, dh, jnp.float32)
        x = _x(4, 1, 64, d)
        full, _ = ssm.mlstm_chunked(params, x, chunk=16)
        _, st = ssm.mlstm_chunked(params, x[:, :32], chunk=16)
        second, _ = ssm.mlstm_chunked(params, x[:, 32:], chunk=16, state=st)
        np.testing.assert_allclose(
            np.asarray(second), np.asarray(full[:, 32:]), atol=2e-4, rtol=2e-3
        )

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=100), st.sampled_from([7, 30, 50]))
    def test_property_ragged_lengths(self, seed, s):
        d, h, dh = 32, 2, 16
        params = ssm.mlstm_init(jax.random.PRNGKey(seed), d, h, dh, jnp.float32)
        x = _x(seed + 1, 1, s, d)
        seq = ssm.mlstm_sequential(params, x)
        par, _ = ssm.mlstm_chunked(params, x, chunk=16)
        np.testing.assert_allclose(np.asarray(par), np.asarray(seq), atol=3e-4, rtol=3e-3)


class TestMamba:
    def test_prefill_matches_decode(self):
        d_model, d_inner, n, k = 32, 32, 8, 4
        params = ssm.mamba_init(
            jax.random.PRNGKey(0), d_model, d_inner, n, k, jnp.float32
        )
        s = 20
        x = _x(1, 2, s, d_model)
        full = ssm.mamba_apply(params, x, chunk=8)
        state = ssm.mamba_init_state(params, 2)
        outs = []
        for t in range(s):
            y, state = ssm.mamba_decode_step(params, state, x[:, t])
            outs.append(y)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec), np.asarray(full), atol=2e-4, rtol=2e-3
        )

    @pytest.mark.parametrize("chunk", [4, 16, 64])
    def test_chunk_size_invariance(self, chunk):
        d_model, d_inner, n, k = 32, 32, 8, 4
        params = ssm.mamba_init(
            jax.random.PRNGKey(2), d_model, d_inner, n, k, jnp.float32
        )
        x = _x(3, 1, 48, d_model)
        ref = ssm.mamba_apply(params, x, chunk=48)
        got = ssm.mamba_apply(params, x, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4, rtol=1e-3)


class TestSLSTM:
    def test_apply_matches_decode(self):
        d, h = 32, 2
        params = ssm.slstm_init(jax.random.PRNGKey(0), d, h, d // h, jnp.float32)
        s = 16
        x = _x(1, 2, s, d)
        full, _ = ssm.slstm_apply(params, x)
        state = ssm.slstm_init_state(2, h, d // h)
        outs = []
        for t in range(s):
            y, state = ssm.slstm_decode_step(params, state, x[:, t])
            outs.append(y)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec), np.asarray(full), atol=2e-5, rtol=1e-4
        )
