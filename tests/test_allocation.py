"""Tests for OP allocation (paper §5.5) — closed form vs oracle optimum.

Reproduces the paper's Figs. 4/5 claim: the closed form (eq. 8) is on average
within ~1% of the hill-climbed optimum, worst cases within ~2–9% for very
skewed workloads.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    allocate_by_frequency,
    allocate_by_size,
    allocate_closed_form,
    group_wa,
    hillclimb_allocation,
    optimal_allocation,
    total_wa,
)


def _wa(s, p, op):
    return float(total_wa(jnp.asarray(s), jnp.asarray(p), jnp.asarray(op)))


class TestPolicies:
    def test_size_policy_sums(self):
        s = jnp.asarray([100.0, 300.0, 600.0])
        op = allocate_by_size(s, 500.0)
        assert float(jnp.sum(op)) == pytest.approx(500.0, rel=1e-6)
        np.testing.assert_allclose(np.asarray(op), [50.0, 150.0, 300.0], rtol=1e-5)

    def test_frequency_policy_sums(self):
        p = jnp.asarray([0.1, 0.9])
        op = allocate_by_frequency(p, 1000.0)
        np.testing.assert_allclose(np.asarray(op), [100.0, 900.0], rtol=1e-5)

    def test_closed_form_is_average_and_sums(self):
        s = jnp.asarray([1000.0, 1000.0])
        p = jnp.asarray([0.1, 0.9])
        op_total = 600.0
        cf = allocate_closed_form(s, p, op_total, cold_rule=False)
        by_s = allocate_by_size(s, op_total)
        by_p = allocate_by_frequency(p, op_total)
        np.testing.assert_allclose(
            np.asarray(cf), np.asarray(0.5 * (by_s + by_p)), rtol=1e-5
        )
        assert float(jnp.sum(cf)) == pytest.approx(op_total, rel=1e-5)

    def test_size_policy_equalizes_delta(self):
        # §5.5.1: greedy-across-groups equalizes δ — eq. 6 realizes that point.
        s = jnp.asarray([500.0, 2000.0, 8000.0])
        op = allocate_by_size(s, 3000.0)
        from repro.core import group_delta

        d = np.asarray(group_delta(s, op))
        assert np.ptp(d) < 1e-4

    def test_cold_rule_triggers(self):
        # Coldest group 1000× colder per page than the rest → fixed 5% alloc.
        s = jnp.asarray([10_000.0, 1_000.0, 1_000.0])
        p = jnp.asarray([0.0001, 0.4999, 0.5])
        op_total = 5_000.0
        cf = allocate_closed_form(s, p, op_total, cold_rule=True)
        assert float(cf[0]) == pytest.approx(0.05 * 1_000.0, rel=1e-4)
        assert float(jnp.sum(cf)) == pytest.approx(op_total, rel=1e-4)
        # And the cold rule should HELP: less WA than the raw closed form.
        raw = allocate_closed_form(s, p, op_total, cold_rule=False)
        assert _wa(s, p, cf) <= _wa(s, p, raw) + 1e-6


class TestNearOptimality:
    """The paper's Fig. 4/5 style sweep (reduced Q for CI speed)."""

    def _sweep(self, n_groups, q, lba_pba):
        # Partition size-space and frequency-space into Q chunks; enumerate a
        # spread of configurations (paper §5.5.3's brute-force methodology).
        rng = np.random.default_rng(n_groups * 100 + q)
        lba = 100_000.0
        op_total = lba * (1.0 / lba_pba - 1.0)
        rel_errs = []
        for _ in range(12):
            s_chunks = rng.multinomial(q - n_groups, np.ones(n_groups) / n_groups) + 1
            p_chunks = rng.multinomial(q - n_groups, np.ones(n_groups) / n_groups) + 1
            s = s_chunks / q * lba
            p = p_chunks / q
            cf = allocate_closed_form(
                jnp.asarray(s), jnp.asarray(p), op_total, cold_rule=False
            )
            opt = optimal_allocation(jnp.asarray(s), jnp.asarray(p), jnp.asarray(op_total))
            wa_cf = _wa(s, p, cf)
            wa_opt = _wa(s, p, opt)
            assert wa_opt <= wa_cf + 1e-4, "optimum must not be worse"
            rel_errs.append((wa_cf - wa_opt) / wa_opt)
        return np.asarray(rel_errs)

    @pytest.mark.parametrize("n_groups", [2, 3, 5])
    def test_closed_form_near_optimal(self, n_groups):
        errs = self._sweep(n_groups, q=10, lba_pba=0.7)
        # Paper Fig. 4 (Q=10): average < 1%, max ≈ 2%.
        assert errs.mean() < 0.015, f"avg {errs.mean():.4f}"
        assert errs.max() < 0.06, f"max {errs.max():.4f}"

    @pytest.mark.parametrize("lba_pba", [0.6, 0.8, 0.9])
    def test_closed_form_across_op_levels(self, lba_pba):
        errs = self._sweep(3, q=10, lba_pba=lba_pba)
        assert errs.mean() < 0.02

    def test_hillclimb_agrees_with_convex_opt(self):
        s = jnp.asarray([30_000.0, 70_000.0])
        p = jnp.asarray([0.8, 0.2])
        op_total = 40_000.0
        hc = hillclimb_allocation(s, p, op_total, block_pages=128)
        opt = optimal_allocation(s, p, jnp.asarray(op_total))
        assert _wa(s, p, hc) == pytest.approx(_wa(s, p, opt), rel=5e-3)

    def test_2modal_matches_fig3_shape(self):
        # Fig. 3: scan the division point for a 2-group workload; the optimum
        # must sit between the size-only and frequency-only division points,
        # and eq. 8 (their average) must be within a few % of the optimum WA.
        s = jnp.asarray([50_000.0, 50_000.0])
        p = jnp.asarray([0.2, 0.8])
        op_total = 30_000.0
        fracs = np.linspace(0.02, 0.98, 97)
        was = np.asarray(
            [_wa(s, p, jnp.asarray([f * op_total, (1 - f) * op_total])) for f in fracs]
        )
        best = was.min()
        cf = allocate_closed_form(s, p, op_total, cold_rule=False)
        assert _wa(s, p, cf) < best * 1.03


class TestPropertyBased:
    @settings(max_examples=40, deadline=None)
    @given(
        st.integers(min_value=2, max_value=8),
        st.floats(min_value=0.55, max_value=0.95),
        st.integers(min_value=0, max_value=10_000),
    )
    def test_closed_form_valid_simplex(self, n, lba_pba, seed):
        rng = np.random.default_rng(seed)
        s = rng.uniform(1.0, 100.0, n)
        s = s / s.sum() * 100_000.0
        p = rng.uniform(0.0, 1.0, n)
        p = p / p.sum()
        op_total = 100_000.0 * (1.0 / lba_pba - 1.0)
        cf = np.asarray(
            allocate_closed_form(jnp.asarray(s), jnp.asarray(p), op_total)
        )
        assert (cf >= -1e-3).all(), "allocations must be non-negative"
        assert cf.sum() == pytest.approx(op_total, rel=1e-4), "must spend all OP"

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_closed_form_beats_single_group_mixing(self, seed):
        # Separating groups and allocating per eq. 8 should never be worse
        # than the no-separation baseline WA at the same total OP (grey line
        # in Fig. 10) for genuinely skewed workloads.
        rng = np.random.default_rng(seed)
        n = rng.integers(2, 6)
        s = rng.uniform(10.0, 100.0, n)
        s = s / s.sum() * 100_000.0
        p = rng.dirichlet(np.ones(n) * 0.3) + 1e-4
        p = p / p.sum()
        hit = p / s
        if hit.max() / hit.min() < 4.0:
            return  # not skewed enough for a guaranteed win
        op_total = 100_000.0 * (1.0 / 0.7 - 1.0)
        cf = allocate_closed_form(jnp.asarray(s), jnp.asarray(p), op_total)
        wa_sep = _wa(s, p, cf)
        wa_mix = float(group_wa(jnp.asarray(100_000.0), jnp.asarray(op_total)))
        assert wa_sep < wa_mix * 1.02
