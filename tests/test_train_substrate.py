"""Training substrate tests: optimizer, loop, checkpoint/restart, data
pipeline determinism, gradient compression, fault tolerance."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, TokenStream
from repro.models.registry import get_config, get_model, smoke_config
from repro.sharding.gradient import (
    compress_tree,
    decompress_tree,
    error_feedback_step,
    init_residual,
)
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.fault_tolerance import RunnerConfig, TrainRunner
from repro.train.optimizer import OptimizerConfig, adamw_init, adamw_update, lr_schedule
from repro.train.train_loop import TrainConfig, init_state, make_train_step

SMOKE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


@pytest.fixture(scope="module")
def api():
    return get_model(smoke_config(get_config("internlm2-1.8b")))


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
        opt = adamw_init(params)
        cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(grads, opt, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_lr_schedule_shape(self):
        cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(5e-4)
        assert lrs[2] == pytest.approx(1e-3)
        assert lrs[2] > lrs[3] > lrs[4]
        assert lrs[4] == pytest.approx(1e-4, rel=0.05)

    def test_grad_clip(self):
        params = {"w": jnp.zeros(3)}
        opt = adamw_init(params)
        cfg = OptimizerConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, weight_decay=0.0)
        _, _, metrics = adamw_update({"w": jnp.asarray([1e6, 0.0, 0.0])}, opt, params, cfg)
        assert metrics["grad_norm"] > 1e5  # reported pre-clip


class TestTrainLoop:
    def test_loss_decreases(self, api):
        tcfg = TrainConfig(
            opt=OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=60),
            n_microbatches=1,
        )
        step = jax.jit(make_train_step(api, tcfg))
        state = init_state(api, jax.random.PRNGKey(0))
        stream = TokenStream(DataConfig(api.cfg.vocab, SMOKE.seq_len, SMOKE.global_batch))
        losses = []
        for i in range(30):
            state, metrics = step(state, stream.batch(i))
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])

    def test_microbatching_matches_full_batch(self, api):
        tcfg1 = TrainConfig(n_microbatches=1)
        tcfg4 = TrainConfig(n_microbatches=4)
        s1 = init_state(api, jax.random.PRNGKey(1))
        s4 = jax.tree_util.tree_map(lambda x: x, s1)
        stream = TokenStream(DataConfig(api.cfg.vocab, SMOKE.seq_len, SMOKE.global_batch))
        batch = stream.batch(0)
        s1, m1 = jax.jit(make_train_step(api, tcfg1))(s1, batch)
        s4, m4 = jax.jit(make_train_step(api, tcfg4))(s4, batch)
        # same data, same update (fp32 accumulation) → near-identical params
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
            s1["params"], s4["params"],
        )
        assert max(jax.tree_util.tree_leaves(d)) < 5e-5


class TestCheckpoint:
    def test_roundtrip_and_retention(self, api):
        state = init_state(api, jax.random.PRNGKey(2))
        with tempfile.TemporaryDirectory() as d:
            for s in (10, 20, 30):
                save_checkpoint(d, state, s, keep=2)
            assert latest_step(d) == 30
            import pathlib

            kept = sorted(p.name for p in pathlib.Path(d).glob("step_*"))
            assert kept == ["step_20", "step_30"]
            target = jax.eval_shape(lambda: init_state(api, jax.random.PRNGKey(0)))
            restored, step = restore_checkpoint(d, target)
            assert step == 30
            for a, b in zip(
                jax.tree_util.tree_leaves(restored),
                jax.tree_util.tree_leaves(state),
            ):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_preserves_training(self, api):
        """checkpoint → restart → identical continued trajectory."""
        tcfg = TrainConfig()
        step_fn = jax.jit(make_train_step(api, tcfg))
        stream = TokenStream(DataConfig(api.cfg.vocab, SMOKE.seq_len, SMOKE.global_batch))
        state = init_state(api, jax.random.PRNGKey(3))
        for i in range(3):
            state, _ = step_fn(state, stream.batch(i))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, state, 3)
            cont, _ = step_fn(state, stream.batch(3))
            target = jax.eval_shape(lambda: init_state(api, jax.random.PRNGKey(0)))
            restored, _ = restore_checkpoint(d, target)
            cont2, _ = step_fn(restored, stream.batch(3))
            a = jax.tree_util.tree_leaves(cont["params"])[0]
            b = jax.tree_util.tree_leaves(cont2["params"])[0]
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
            )


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab=97, seq_len=16, global_batch=4, seed=1)
        s1, s2 = TokenStream(cfg), TokenStream(cfg)
        b1, b2 = s1.batch(7), s2.batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(s1.batch(8)["tokens"], b1["tokens"])

    def test_sharding_partitions_global_batch(self):
        full = DataConfig(vocab=97, seq_len=8, global_batch=8, seed=2)
        shards = [
            DataConfig(vocab=97, seq_len=8, global_batch=8, seed=2, num_shards=2, shard_id=i)
            for i in range(2)
        ]
        assert TokenStream(shards[0]).batch(0)["tokens"].shape[0] == 4
        # different shards see different data
        a = TokenStream(shards[0]).batch(0)["tokens"]
        b = TokenStream(shards[1]).batch(0)["tokens"]
        assert not np.array_equal(a, b)

    def test_labels_are_next_tokens(self):
        cfg = DataConfig(vocab=53, seq_len=12, global_batch=2)
        b = TokenStream(cfg).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestGradientCompression:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=0, max_value=1000), st.sampled_from(["int8", "bf16"]))
    def test_roundtrip_error_bounded(self, seed, mode):
        rng = jax.random.PRNGKey(seed)
        tree = {"a": jax.random.normal(rng, (64,)) * 3.0, "b": jax.random.normal(rng, (8, 8))}
        payload, meta = compress_tree(tree, rng, mode=mode)
        back = decompress_tree(payload, meta, tree)
        for k in tree:
            scale = float(jnp.max(jnp.abs(tree[k])))
            err = float(jnp.max(jnp.abs(back[k] - tree[k])))
            bound = scale / 64 if mode == "int8" else scale / 64
            assert err <= bound, (k, err, bound)

    def test_error_feedback_unbiased_accumulation(self):
        """With error feedback, the SUM of delivered gradients tracks the sum
        of true gradients (compression noise cancels instead of biasing)."""
        rng = jax.random.PRNGKey(0)
        true = {"w": jnp.full((32,), 0.01)}  # tiny grads: worst case for int8
        residual = init_residual(true)
        delivered = jnp.zeros((32,))
        for i in range(50):
            g, residual = error_feedback_step(
                true, residual, jax.random.fold_in(rng, i), mode="int8"
            )
            delivered += g["w"]
        target = 50 * 0.01
        np.testing.assert_allclose(np.asarray(delivered), target, rtol=0.05)


class TestFaultTolerance:
    def test_recovers_from_injected_failure(self, api):
        tcfg = TrainConfig()
        step_fn = jax.jit(make_train_step(api, tcfg))
        stream = TokenStream(DataConfig(api.cfg.vocab, SMOKE.seq_len, SMOKE.global_batch))
        with tempfile.TemporaryDirectory() as d:
            runner = TrainRunner(
                step_fn,
                init_state(api, jax.random.PRNGKey(4)),
                stream.batch,
                RunnerConfig(total_steps=12, checkpoint_every=4, checkpoint_dir=d),
                failure_at=6,
            )
            out = runner.run()
            assert out["final_step"] == 12
            assert out["retries"] == 1
            assert out["recoveries"] >= 1
            assert latest_step(d) == 12

    def test_elastic_restore_same_content(self, api):
        """A checkpoint restores identically regardless of mesh (here: the
        degenerate 1-device 'mesh change'), because content is logical."""
        state = init_state(api, jax.random.PRNGKey(5))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, state, 1)
            target = jax.eval_shape(lambda: init_state(api, jax.random.PRNGKey(0)))
            restored, _ = restore_checkpoint(d, target)
            a = jax.tree_util.tree_leaves(state["opt"]["master"])[0]
            b = jax.tree_util.tree_leaves(restored["opt"]["master"])[0]
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
