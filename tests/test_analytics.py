"""Tests for the analytical WA model (paper §4 + Appendix A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import analytics as A


class TestBlockLifetime:
    def test_full_decay_matches_harmonic_sum(self):
        # Paper §4.1: expected updates until 0→ via harmonic sum ≈ LBA(ln B + γ).
        B, LBA = 128, 100_000
        harmonic = LBA * sum(1.0 / i for i in range(1, B + 1))
        euler = LBA * (np.log(B) + np.euler_gamma)
        assert abs(harmonic - euler) / harmonic < 1e-3

    def test_eq1_eq2_inverse(self):
        B, LBA = 128.0, 1e5
        g = jnp.linspace(1.0, B, 50)
        x = A.block_decay_updates(g, b=B, lba=LBA)
        g2 = A.block_live_pages(x, b=B, lba=LBA)
        np.testing.assert_allclose(np.asarray(g2), np.asarray(g), rtol=1e-5)

    def test_decay_monotone(self):
        B, LBA = 64.0, 5e4
        x = jnp.linspace(0.0, 5 * LBA, 100)
        g = np.asarray(A.block_live_pages(x, b=B, lba=LBA))
        assert (np.diff(g) < 0).all()
        assert g[0] == pytest.approx(B)


class TestEquilibrium:
    def test_eq3_endpoints(self):
        # δ→1 means r→1 (no over-provisioning); δ→0 means r→0.
        assert float(A.op_ratio_from_delta(jnp.asarray(1.0 - 1e-7))) == pytest.approx(
            1.0, abs=1e-4
        )
        # r → 0 as δ → 0 (logarithmically: r = (1-δ)/|ln δ|).
        assert float(A.op_ratio_from_delta(jnp.asarray(1e-9))) < 0.05

    def test_bisection_inverts_eq3(self):
        r = jnp.linspace(0.05, 0.99, 64)
        delta = A.delta_from_op_ratio(r)
        r2 = A.op_ratio_from_delta(delta)
        np.testing.assert_allclose(np.asarray(r2), np.asarray(r), atol=2e-5)

    def test_lambertw_agrees_with_bisection(self):
        # Appendix A (eq. 9) is the same curve as eq. 3: cross-validate.
        r = jnp.linspace(0.1, 0.95, 40)
        d_bis = np.asarray(A.delta_from_op_ratio(r))
        d_lw = np.asarray(A.delta_from_op_ratio_lambertw(r))
        np.testing.assert_allclose(d_lw, d_bis, atol=5e-4)

    def test_known_point_70pct(self):
        # The paper's default LBA/PBA = 0.7 (Table 2). Solve eq. 3 numerically
        # with an independent method (scipy-free secant in numpy).
        r = 0.7

        def f(d):
            return (d - 1.0) / np.log(d) - r

        lo, hi = 1e-9, 1 - 1e-9
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if f(mid) < 0:
                lo = mid
            else:
                hi = mid
        expected = 0.5 * (lo + hi)
        got = float(A.delta_from_op_ratio(jnp.asarray(r)))
        assert got == pytest.approx(expected, abs=1e-5)
        # WA at 70% utilization is modest (paper Fig. 1: ~1.8–2.3 region).
        wa = float(A.wa_from_op_ratio(jnp.asarray(r)))
        assert 1.5 < wa < 3.0

    def test_wa_monotone_in_r(self):
        r = jnp.linspace(0.05, 0.98, 60)
        wa = np.asarray(A.wa_from_op_ratio(r))
        assert (np.diff(wa) > 0).all(), "more utilization ⇒ more WA"
        assert wa[0] >= 1.0

    def test_wa_delta_roundtrip(self):
        d = jnp.linspace(0.01, 0.95, 20)
        np.testing.assert_allclose(
            np.asarray(A.delta_from_wa(A.wa_from_delta(d))), np.asarray(d), rtol=1e-4
        )

    @settings(max_examples=50, deadline=None)
    @given(st.floats(min_value=0.05, max_value=0.98))
    def test_property_inverse_consistency(self, r):
        d = float(A.delta_from_op_ratio(jnp.asarray(r, jnp.float32)))
        assert 0.0 < d < 1.0
        r_back = float(A.op_ratio_from_delta(jnp.asarray(d)))
        assert r_back == pytest.approx(r, abs=1e-4)


class TestLambertW:
    def test_identity(self):
        # W(a)·e^{W(a)} = a on the principal branch.
        a = jnp.linspace(-0.36, 2.0, 50)
        w = A.lambertw0(a)
        np.testing.assert_allclose(
            np.asarray(w * jnp.exp(w)), np.asarray(a), atol=2e-5
        )

    def test_known_values(self):
        assert float(A.lambertw0(jnp.asarray(0.0))) == pytest.approx(0.0, abs=1e-7)
        e = float(np.e)
        assert float(A.lambertw0(jnp.asarray(e))) == pytest.approx(1.0, abs=1e-5)
        assert float(A.lambertw0(jnp.asarray(-1.0 / e))) == pytest.approx(-1.0, abs=2e-2)


@pytest.mark.trim
class TestEffectiveOp:
    """Frankie et al.: trimmed logical space is dynamic over-provisioning."""

    def test_no_trim_is_identity(self):
        r = jnp.linspace(0.3, 0.95, 20)
        np.testing.assert_allclose(
            np.asarray(A.effective_op_ratio(r, 0.0)), np.asarray(r)
        )

    def test_effective_ratio_is_r_times_one_minus_t(self):
        # r_eff = (1-t)·LBA / PBA: the OP pool gains exactly t·LBA pages
        lba, pba, t = 700.0, 1000.0, 0.25
        r_eff = float(A.effective_op_ratio(lba / pba, t))
        assert r_eff == pytest.approx((1 - t) * lba / pba, rel=1e-6)
        op_eff = pba - r_eff * pba
        assert op_eff == pytest.approx((pba - lba) + t * lba, rel=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        st.floats(min_value=0.4, max_value=0.95),
        st.floats(min_value=0.0, max_value=0.8),
        st.floats(min_value=0.0, max_value=0.8),
    )
    def test_wa_monotone_decreasing_in_trim(self, r, t1, t2):
        lo, hi = sorted((t1, t2))
        wa_lo = float(A.wa_with_trim(r, hi))  # more trim → lower WA
        wa_hi = float(A.wa_with_trim(r, lo))
        assert wa_lo <= wa_hi + 1e-6
        assert wa_lo >= 1.0

    def test_composition_matches_manual(self):
        r, t = 0.8, 0.3
        manual = float(A.wa_from_op_ratio(jnp.asarray(r * (1 - t))))
        assert float(A.wa_with_trim(r, t)) == pytest.approx(manual, rel=1e-6)

    def test_grid_broadcasts(self):
        r = jnp.linspace(0.5, 0.9, 5)[:, None]
        t = jnp.asarray([0.0, 0.1, 0.25, 0.5])[None, :]
        wa = A.wa_with_trim(r, t)
        assert wa.shape == (5, 4)
        # decreasing along the trim axis, increasing along utilization
        assert bool(jnp.all(jnp.diff(wa, axis=1) <= 1e-6))
        assert bool(jnp.all(jnp.diff(wa, axis=0) >= -1e-6))
