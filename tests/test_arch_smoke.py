"""Per-architecture smoke tests on reduced configs (CPU, 1 device).

For each of the 10 assigned archs:
  * one forward + loss + grad step: finite loss, finite grads, right shapes;
  * prefill → repeated decode_step consistency against a full forward pass
    (validates KV cache / ring buffer / recurrent state handling).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.models.registry import ALL_ARCHS, get_config, get_model, smoke_config

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _api(arch_id):
    cfg = smoke_config(get_config(arch_id))
    return get_model(cfg)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
class TestSmoke:
    def test_loss_and_grads_finite(self, arch_id, rng):
        api = _api(arch_id)
        params = api.init_params(rng)
        batch = api.make_train_batch(SMOKE_SHAPE, jax.random.PRNGKey(1))
        loss, grads = jax.jit(jax.value_and_grad(api.loss_fn))(params, batch)
        assert np.isfinite(float(loss)), f"{arch_id}: loss not finite"
        assert 0.0 < float(loss) < 20.0, f"{arch_id}: implausible loss {loss}"
        leaves = jax.tree_util.tree_leaves(grads)
        assert leaves, "no grads"
        for leaf in leaves:
            assert bool(jnp.isfinite(leaf).all()), f"{arch_id}: non-finite grad"

    def test_decode_matches_forward(self, arch_id, rng):
        api = _api(arch_id)
        cfg = api.cfg
        params = api.init_params(rng)
        b, s_prompt, n_steps = 2, 16, 4
        total = s_prompt + n_steps
        tokens = jax.random.randint(jax.random.PRNGKey(2), (b, total), 0, cfg.vocab)

        kwargs = {}
        front = 0  # non-text prefix length (image patches) occupying positions
        if cfg.frontend == "vision_patches":
            front = 4
            kwargs["extra_embeds"] = (
                jax.random.normal(jax.random.PRNGKey(3), (b, front, cfg.d_model)) * 0.02
            ).astype(jnp.dtype(cfg.dtype))
        if cfg.frontend == "audio_frames":
            kwargs["frames"] = (
                jax.random.normal(jax.random.PRNGKey(3), (b, 8, cfg.d_model)) * 0.02
            ).astype(jnp.dtype(cfg.dtype))

        # incremental: prefill prompt, then decode the remaining tokens
        logits, cache = api.prefill(
            params, tokens[:, :s_prompt], max_len=front + total, **kwargs
        )
        for i in range(n_steps):
            pos = jnp.full((b,), front + s_prompt + i, jnp.int32)
            logits, cache = jax.jit(api.decode_step)(
                params, cache, tokens[:, s_prompt + i], pos
            )
        # oracle: one prefill over the whole sequence
        logits_full, _ = api.prefill(params, tokens, **kwargs)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(logits_full, np.float32),
            atol=2e-2,
            rtol=2e-2,
        )

    def test_full_config_instantiates(self, arch_id, rng):
        # The FULL config must at least build its shape/param structure
        # abstractly (no allocation) — the dry-run exercises it for real.
        cfg = get_config(arch_id)
        api = get_model(cfg)
        shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))
        n_params = sum(
            int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes)
        )
        assert n_params > 10_000_000, f"{arch_id}: suspiciously small ({n_params})"


class TestShapeSupport:
    def test_long_500k_gating(self):
        from repro.configs.base import SHAPES

        long = SHAPES["long_500k"]
        expected_support = {
            "granite-20b": False,
            "internlm2-1.8b": False,
            "deepseek-coder-33b": False,
            "deepseek-7b": False,
            "xlstm-125m": True,
            "olmoe-1b-7b": False,
            "mixtral-8x22b": True,
            "hymba-1.5b": True,
            "llava-next-34b": False,
            "whisper-large-v3": False,
        }
        for arch, want in expected_support.items():
            got = get_config(arch).supports_shape(long)
            assert got == want, f"{arch}: supports long_500k={got}, want {want}"

    def test_param_counts_roughly_match_names(self):
        # Sanity: the billion-scale names should be in the right ballpark.
        expected = {
            "granite-20b": (10e9, 35e9),
            "internlm2-1.8b": (1.2e9, 3e9),
            "deepseek-coder-33b": (20e9, 45e9),
            "deepseek-7b": (5e9, 10e9),
            "xlstm-125m": (0.08e9, 0.3e9),
            "olmoe-1b-7b": (4e9, 9e9),
            "mixtral-8x22b": (90e9, 180e9),
            "hymba-1.5b": (1e9, 2.5e9),
            "llava-next-34b": (25e9, 45e9),
            "whisper-large-v3": (1e9, 2.5e9),
        }
        for arch, (lo, hi) in expected.items():
            api = get_model(get_config(arch))
            shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0)))
            n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
            assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]B"
