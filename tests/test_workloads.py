"""Workload generator tests (pure numpy — no sim runs)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import workloads as W


class TestPhases:
    def test_sizes_partition_lba(self):
        lba = 10_001
        for phase in (
            W.uniform(lba, 10),
            W.two_modal(lba, 10),
            W.exponential_groups(lba, 10),
            W.tpcc_like(lba, 10),
        ):
            assert sum(phase.sizes) == lba
            assert abs(sum(phase.probs) - 1.0) < 1e-9

    def test_sample_respects_group_probs(self):
        lba = 20_000
        phase = W.two_modal(lba, 100_000, p_hot=0.9, frac_hot=0.5)
        rng = np.random.default_rng(0)
        lbas = phase.sample(rng)
        assert lbas.min() >= 0 and lbas.max() < lba
        hot_start = phase.sizes[0]
        frac_hot_writes = (lbas >= hot_start).mean()
        assert frac_hot_writes == pytest.approx(0.9, abs=0.01)

    def test_page_rate_consistent_with_probs(self):
        phase = W.exponential_groups(9_999, 10)
        rate = phase.page_rate()
        # aggregate rate per group == group prob
        off = 0
        for s, p in zip(phase.sizes, phase.probs):
            assert rate[off:off + s].sum() == pytest.approx(p, rel=1e-5)
            off += s

    def test_swap_phases_swap_probs(self):
        a, b = W.swap_phases(10_000, 5, p=(0.1, 0.9))
        assert a.probs == (0.1, 0.9)
        assert b.probs == (0.9, 0.1)
        assert a.sizes == b.sizes

    def test_pairwise_swap(self):
        base = W.exponential_groups(10_000, 5)
        sw = W.pairwise_swap(base, 0, 4, 5)
        assert sw.probs[0] == base.probs[4]
        assert sw.probs[4] == base.probs[0]
        assert sw.probs[1:4] == base.probs[1:4]

    def test_tpcc_shape_matches_fig9(self):
        """Fig. 9: two clusters, hot ~8× hotter per page, cold majority."""
        phase = W.tpcc_like(100_000, 10)
        rates = [p / s for s, p in zip(phase.sizes, phase.probs)]
        assert rates[2] / rates[1] == pytest.approx(8.0, rel=0.05)
        assert phase.sizes[0] / 100_000 == pytest.approx(0.54, abs=0.01)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=100, max_value=100_000), st.integers(0, 999))
    def test_property_split_sizes_exact(self, lba, seed):
        rng = np.random.default_rng(seed)
        fracs = rng.dirichlet(np.ones(rng.integers(2, 6)))
        sizes = W.split_sizes(lba, fracs)
        assert sum(sizes) == lba
        assert all(s >= 0 for s in sizes)


@pytest.mark.trim
class TestOpStreams:
    """Op-stream phases: per-group trim probabilities + the two samplers."""

    def test_pure_write_sample_ops_matches_sample(self):
        """On a trim-free phase, sample_ops consumes exactly the draws
        sample does — the bit-compat anchor for the op engine."""
        phase = W.two_modal(10_000, 5_000)
        assert not phase.has_trim
        lbas = phase.sample(np.random.default_rng(42))
        ops, lbas2 = phase.sample_ops(np.random.default_rng(42))
        np.testing.assert_array_equal(lbas, lbas2)
        assert not ops.any()

    def test_sample_rejects_op_phase(self):
        phase = W.trimmed(W.uniform(1_000, 10), 0.5)
        with pytest.raises(AssertionError):
            phase.sample(np.random.default_rng(0))

    def test_trimmed_scalar_and_per_group(self):
        base = W.two_modal(10_000, 10)
        assert W.trimmed(base, 0.3).trim_probs == (0.3, 0.3)
        assert W.trimmed(base, (0.0, 0.4)).trim_probs == (0.0, 0.4)
        with pytest.raises(AssertionError):
            W.trimmed(base, (0.1,))  # wrong group count
        with pytest.raises(AssertionError):
            W.trimmed(base, 1.5)  # not a probability

    def test_trim_rate_per_group(self):
        phase = W.trimmed(
            W.two_modal(20_000, 100_000, p_hot=0.9, frac_hot=0.5),
            (0.0, 0.4),
        )
        ops, lbas = phase.sample_ops(np.random.default_rng(1))
        hot = lbas >= phase.sizes[0]
        assert ops[~hot].mean() == 0.0
        assert ops[hot].mean() == pytest.approx(0.4, abs=0.01)

    def test_monotone_coupling_across_trim_fracs(self):
        """Same seed → the t2-trim set contains the t1-trim set (t1 < t2):
        the variance-free coupling the monotonicity acceptance test uses."""
        base = W.uniform(5_000, 20_000)
        o1, l1 = W.trimmed(base, 0.1).sample_ops(np.random.default_rng(7))
        o2, l2 = W.trimmed(base, 0.4).sample_ops(np.random.default_rng(7))
        np.testing.assert_array_equal(l1, l2)
        assert (o2 >= o1).all()

    def test_utilization_sweep_helper(self):
        phases = W.utilization_sweep(10_000, 50, trim_fracs=(0.0, 0.25))
        assert len(phases) == 2
        assert not phases[0].has_trim
        assert phases[1].trim_probs == (0.25,)

    def test_tpcc_churn_shape(self):
        """Churn keeps the tpcc_like temperature shape; only the hot
        (orders) cluster churns hard, the cold majority never trims."""
        churn = W.tpcc_churn(100_000, 10)
        base = W.tpcc_like(100_000, 10)
        assert churn.sizes == base.sizes and churn.probs == base.probs
        assert churn.trim_probs[0] == 0.0
        assert churn.trim_probs[2] == pytest.approx(1 / 3, rel=1e-6)
        assert churn.has_trim

    def test_phase_param_arrays_carry_trim_probs(self):
        phases = [W.tpcc_churn(9_999, 10), W.uniform(9_999, 10)]
        params = W.phase_param_arrays(phases, p_max=3)
        assert params["trim_probs"].shape == params["probs"].shape
        np.testing.assert_allclose(
            params["trim_probs"][0, :3], np.asarray(phases[0].trim_probs)
        )
        assert (params["trim_probs"][1:] == 0).all()

    def test_device_sampler_ops_distribution(self):
        """sample_phases_device(with_ops=True) draws ops at the phase's
        per-group trim rates (same distribution as the host sampler)."""
        import jax

        phase = W.trimmed(
            W.two_modal(20_000, 50_000, p_hot=0.9, frac_hot=0.5),
            (0.0, 0.3),
        )
        params = W.phase_param_arrays([phase])
        ops, lbas = W.sample_phases_device(
            jax.random.PRNGKey(0), params, phase.n_writes, with_ops=True
        )
        ops, lbas = np.asarray(ops), np.asarray(lbas)
        hot = lbas >= phase.sizes[0]
        assert ops[~hot].mean() == 0.0
        assert ops[hot].mean() == pytest.approx(0.3, abs=0.02)
        # pure-write path is unchanged: no third key consumed
        lbas_plain = np.asarray(W.sample_phases_device(
            jax.random.PRNGKey(0), params, phase.n_writes
        ))
        assert lbas_plain.shape == lbas.shape
