"""Workload generator tests (pure numpy — no sim runs)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import workloads as W


class TestPhases:
    def test_sizes_partition_lba(self):
        lba = 10_001
        for phase in (
            W.uniform(lba, 10),
            W.two_modal(lba, 10),
            W.exponential_groups(lba, 10),
            W.tpcc_like(lba, 10),
        ):
            assert sum(phase.sizes) == lba
            assert abs(sum(phase.probs) - 1.0) < 1e-9

    def test_sample_respects_group_probs(self):
        lba = 20_000
        phase = W.two_modal(lba, 100_000, p_hot=0.9, frac_hot=0.5)
        rng = np.random.default_rng(0)
        lbas = phase.sample(rng)
        assert lbas.min() >= 0 and lbas.max() < lba
        hot_start = phase.sizes[0]
        frac_hot_writes = (lbas >= hot_start).mean()
        assert frac_hot_writes == pytest.approx(0.9, abs=0.01)

    def test_page_rate_consistent_with_probs(self):
        phase = W.exponential_groups(9_999, 10)
        rate = phase.page_rate()
        # aggregate rate per group == group prob
        off = 0
        for s, p in zip(phase.sizes, phase.probs):
            assert rate[off:off + s].sum() == pytest.approx(p, rel=1e-5)
            off += s

    def test_swap_phases_swap_probs(self):
        a, b = W.swap_phases(10_000, 5, p=(0.1, 0.9))
        assert a.probs == (0.1, 0.9)
        assert b.probs == (0.9, 0.1)
        assert a.sizes == b.sizes

    def test_pairwise_swap(self):
        base = W.exponential_groups(10_000, 5)
        sw = W.pairwise_swap(base, 0, 4, 5)
        assert sw.probs[0] == base.probs[4]
        assert sw.probs[4] == base.probs[0]
        assert sw.probs[1:4] == base.probs[1:4]

    def test_tpcc_shape_matches_fig9(self):
        """Fig. 9: two clusters, hot ~8× hotter per page, cold majority."""
        phase = W.tpcc_like(100_000, 10)
        rates = [p / s for s, p in zip(phase.sizes, phase.probs)]
        assert rates[2] / rates[1] == pytest.approx(8.0, rel=0.05)
        assert phase.sizes[0] / 100_000 == pytest.approx(0.54, abs=0.01)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=100, max_value=100_000), st.integers(0, 999))
    def test_property_split_sizes_exact(self, lba, seed):
        rng = np.random.default_rng(seed)
        fracs = rng.dirichlet(np.ones(rng.integers(2, 6)))
        sizes = W.split_sizes(lba, fracs)
        assert sum(sizes) == lba
        assert all(s >= 0 for s in sizes)
