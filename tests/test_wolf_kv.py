"""Wolf-KV tests: manager invariants + economics, paged-model consistency,
and the end-to-end serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ShapeConfig
from repro.kvcache.manager import WolfKVManager
from repro.models.registry import get_config, get_model, smoke_config


class TestManager:
    def _churn(self, mgr, rng, n_seqs=6, n_ops=3000, max_live=24):
        """Steady-state churn: each sequence held at ≤ max_live tokens (so
        the workload fits the pool; overflow is admission control's job)."""
        for sid in range(n_seqs):
            mgr.add_sequence(sid, sid % mgr.n_groups)
        for _ in range(n_ops):
            sid = int(rng.integers(n_seqs))
            mgr.append_token(sid)
            seq = mgr.seqs[sid]
            alive = np.flatnonzero(seq.valid[: seq.cache_len])
            if len(alive) > max_live:
                mgr.evict_token(sid, int(rng.choice(alive[:-2])))
        return mgr

    def test_basic_lifecycle(self):
        mgr = WolfKVManager(64, 8, 2)
        mgr.add_sequence(0, 0)
        for _ in range(20):
            mgr.append_token(0)
        assert mgr.cache_len(0) == 20
        assert mgr.groups[0].size_slots == 20
        mgr.check_invariants()
        mgr.finish_sequence(0)
        assert len(mgr.free) == 64
        assert mgr.write_amplification == 1.0  # no churn → no copies

    def test_window_eviction_is_cheap(self):
        # prefix pages die whole → blocks freed without copies
        mgr = WolfKVManager(64, 8, 1)
        mgr.add_sequence(0, 0)
        for t in range(200):
            mgr.append_token(0)
            if t >= 32:
                mgr.evict_token(0, t - 32)
        mgr.check_invariants()
        assert mgr.copied == 0, "in-order eviction must not trigger copies"

    def test_compaction_reclaims(self):
        mgr = WolfKVManager(16, 8, 1, adaptive=False)
        mgr.add_sequence(0, 0)
        rng = np.random.default_rng(0)
        for _ in range(80):
            mgr.append_token(0)
        # punch scattered holes, then force GC
        alive = np.flatnonzero(mgr.seqs[0].valid[:80])
        for ci in rng.choice(alive, 40, replace=False):
            mgr.evict_token(0, int(ci))
        before = mgr.groups[0].n_blocks
        copied = mgr.gc_group(0)
        mgr.check_invariants()
        assert copied > 0
        assert mgr.groups[0].n_blocks < before
        moves = mgr.drain_moves()
        assert len(moves) == copied

    def test_more_spare_means_less_wa(self):
        """The paper's core curve (eq. 3): more over-provisioning → lower WA,
        here for the KV cache under random-eviction churn. The group's block
        budget IS its (s + OP): we pin it (adaptive off) and sweep OP."""
        was = []
        for budget_blocks in (20, 28, 44):
            mgr = WolfKVManager(64, 8, 1, adaptive=False)
            mgr.groups[0].alloc_blocks = budget_blocks
            rng = np.random.default_rng(1)
            mgr.add_sequence(0, 0)
            # steady state: ~128 live slots (16 blocks), churn 1-in-1-out
            for t in range(128):
                mgr.append_token(0)
            for _ in range(4000):
                mgr.append_token(0)
                seq = mgr.seqs[0]
                alive = np.flatnonzero(seq.valid[: seq.cache_len])
                mgr.evict_token(0, int(rng.choice(alive[:-1])))
            mgr.check_invariants()
            was.append(mgr.write_amplification)
        assert was[0] > was[1] > was[2], was
        assert was[0] > 1.2, was
        assert was[2] < was[0] * 0.75, was

    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(min_value=0, max_value=1000),
        st.integers(min_value=1, max_value=3),
        st.booleans(),
    )
    def test_invariants_random(self, seed, n_groups, adaptive):
        rng = np.random.default_rng(seed)
        mgr = WolfKVManager(96, 8, n_groups, adaptive=adaptive)
        self._churn(mgr, rng)
        mgr.check_invariants()
        assert mgr.write_amplification >= 1.0

    def test_adaptive_beats_static_after_churn_swap(self):
        """The paper's swap experiment at the KV layer: two sequence classes
        swap churn behaviour; Wolf's measured allocation + movement ops beat
        a frozen split."""

        def run(adaptive):
            mgr = WolfKVManager(128, 8, 2, adaptive=adaptive, interval=256)
            rng = np.random.default_rng(2)
            mgr.add_sequence(0, 0)  # class A
            mgr.add_sequence(1, 1)  # class B
            for _ in range(96):
                mgr.append_token(0)
                mgr.append_token(1)
            if not adaptive:
                # freeze a split fitted to phase 1 (B hot)
                mgr.groups[0].alloc_blocks = 20
                mgr.groups[1].alloc_blocks = 90

            def churn(sid, hot):
                mgr.append_token(sid)
                if hot:
                    seq = mgr.seqs[sid]
                    alive = np.flatnonzero(seq.valid[: seq.cache_len])
                    mgr.evict_token(sid, int(rng.choice(alive[:-1])))

            # phase 1: B hot / A cold-ish growth capped by finishing tokens
            for _ in range(2500):
                churn(1, True)
                if rng.random() < 0.1:
                    churn(0, False)
            mark = mgr.mark()
            # phase 2 (swap): A hot / B idle
            for _ in range(2500):
                churn(0, True)
            mgr.check_invariants()
            return mgr.wa_since(mark)

        wa_adaptive = run(True)
        wa_static = run(False)
        assert wa_adaptive < wa_static, (wa_adaptive, wa_static)


class TestPagedModelConsistency:
    """paged decode (block tables + kernel) ≡ dense-cache decode."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg = smoke_config(get_config("internlm2-1.8b"))
        api = get_model(cfg)
        params = api.init_params(jax.random.PRNGKey(0))
        return cfg, api, params

    def test_decode_matches_dense(self, setup):
        from repro.kvcache.manager import WolfKVManager
        from repro.serving.paged_model import (
            init_pools, paged_decode_step, paged_prefill,
        )

        cfg, api, params = setup
        b, s_prompt, n_steps = 2, 12, 3
        page, n_blocks, max_pages = 8, 64, 8
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (b, s_prompt + n_steps), 0, cfg.vocab
        )
        # dense path
        logits_d, cache = api.prefill(
            params, tokens[:, :s_prompt], max_len=s_prompt + n_steps
        )
        # paged path
        mgr = WolfKVManager(n_blocks, page, 1)
        pools = init_pools(cfg, n_blocks, page)
        wb = np.zeros((b, s_prompt), np.int32)
        ws = np.zeros((b, s_prompt), np.int32)
        for i in range(b):
            mgr.add_sequence(i, 0)
            for t in range(s_prompt):
                wb[i, t], ws[i, t] = mgr.append_token(i)
        logits_p, pools = paged_prefill(
            params, cfg, pools, tokens[:, :s_prompt],
            jnp.asarray(wb), jnp.asarray(ws),
        )
        np.testing.assert_allclose(
            np.asarray(logits_p), np.asarray(logits_d), atol=1e-3, rtol=1e-3
        )
        for i in range(n_steps):
            pos = jnp.full((b,), s_prompt + i, jnp.int32)
            logits_d, cache = api.decode_step(
                params, cache, tokens[:, s_prompt + i], pos
            )
            wb1 = np.zeros(b, np.int32)
            ws1 = np.zeros(b, np.int32)
            for j in range(b):
                wb1[j], ws1[j] = mgr.append_token(j)
            tables = np.stack([mgr.block_table(j, max_pages) for j in range(b)])
            valid = np.stack([mgr.slot_valid(j, max_pages) for j in range(b)])
            lengths = np.asarray([mgr.cache_len(j) for j in range(b)], np.int32)
            logits_p, pools = paged_decode_step(
                params, cfg, pools,
                jnp.asarray(tables), jnp.asarray(valid, jnp.int8),
                jnp.asarray(lengths), jnp.asarray(wb1), jnp.asarray(ws1),
                tokens[:, s_prompt + i], pos,
            )
            np.testing.assert_allclose(
                np.asarray(logits_p), np.asarray(logits_d), atol=2e-3, rtol=2e-3
            )

    def test_compaction_preserves_logits(self, setup):
        """Evict tokens, compact (gc_compact kernel moves the pool), and the
        paged logits must equal a dense run with the same tokens masked."""
        from repro.kvcache.manager import WolfKVManager
        from repro.serving.paged_model import (
            apply_moves, init_pools, paged_decode_step, paged_prefill,
        )

        cfg, api, params = setup
        page, n_blocks, max_pages = 8, 64, 8
        s_prompt = 24
        tokens = jax.random.randint(jax.random.PRNGKey(2), (1, s_prompt + 1), 0, cfg.vocab)
        mgr = WolfKVManager(n_blocks, page, 1, adaptive=False)
        pools = init_pools(cfg, n_blocks, page)
        mgr.add_sequence(0, 0)
        wb = np.zeros((1, s_prompt), np.int32)
        ws = np.zeros((1, s_prompt), np.int32)
        for t in range(s_prompt):
            wb[0, t], ws[0, t] = mgr.append_token(0)
        _, pools = paged_prefill(
            params, cfg, pools, tokens[:, :s_prompt], jnp.asarray(wb), jnp.asarray(ws)
        )
        # logits before eviction (no holes): baseline correctness guaranteed
        # by test_decode_matches_dense; now evict & compact.
        evicted = [3, 4, 5, 6, 7, 11, 13]
        for ci in evicted:
            mgr.evict_token(0, ci)
        copied = mgr.gc_group(0)
        assert copied > 0
        pools = apply_moves(pools, mgr.drain_moves())
        mgr.check_invariants()

        wb1 = np.zeros(1, np.int32)
        ws1 = np.zeros(1, np.int32)
        wb1[0], ws1[0] = mgr.append_token(0)
        tables = mgr.block_table(0, max_pages)[None]
        valid = mgr.slot_valid(0, max_pages)[None]
        lengths = np.asarray([mgr.cache_len(0)], np.int32)
        pos = jnp.asarray([s_prompt], jnp.int32)
        logits_p, pools = paged_decode_step(
            params, cfg, pools,
            jnp.asarray(tables), jnp.asarray(valid, jnp.int8),
            jnp.asarray(lengths), jnp.asarray(wb1), jnp.asarray(ws1),
            tokens[:, s_prompt], pos,
        )
        # dense oracle: same prompt, evicted positions masked via kv_pos=-1
        logits_d, cache = api.prefill(params, tokens[:, :s_prompt], max_len=s_prompt + 1)
        kv_pos = np.asarray(cache["kv_pos"]).copy()
        kv_pos[:, evicted] = -1
        cache = dict(cache, kv_pos=jnp.asarray(kv_pos))
        logits_d, _ = api.decode_step(params, cache, tokens[:, s_prompt], pos)
        np.testing.assert_allclose(
            np.asarray(logits_p), np.asarray(logits_d), atol=2e-3, rtol=2e-3
        )


class TestEngine:
    def test_end_to_end_serving(self):
        from repro.serving.engine import Request, ServingEngine

        cfg = smoke_config(get_config("internlm2-1.8b"))
        eng = ServingEngine(cfg, n_blocks=128, page=8, max_pages_per_seq=16, max_batch=4)
        rng = np.random.default_rng(0)
        for rid in range(6):
            policy = ["append", "h2o:50", "window:16"][rid % 3]
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
                max_new=20,
                policy=policy,
            ))
        summary = eng.run_until_drained(max_steps=200)
        assert summary["appended"] > 0
        assert summary["wa"] >= 1.0
        eng.manager.check_invariants()
        assert len(eng.manager.free) == eng.manager.n_blocks  # all reclaimed
