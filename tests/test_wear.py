"""Wear/endurance layer tests: the (α, β, γ, τ) victim-scoring layer must
reproduce the legacy greedy/LRU argmin selections exactly (per-step oracle
over random block states AND whole-run bit-identity), the erase accounting
must conserve, and the wear analytics must read off the carried aggregates.
"""

import dataclasses
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import managers as M
from repro.core import simulator as S
from repro.core import workloads as W
from repro.core.analytics import (
    dwpd_from_lifetime,
    lifetime_host_writes,
    wear_imbalance,
    wear_variance,
)
from repro.core.ssd import CLOSED, FREE, OPEN, GC_WEIGHT_PRESETS, Geometry

pytestmark = pytest.mark.wear

GEOM = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8, lba_pba=0.7)


def _weights(policy: str) -> jnp.ndarray:
    return jnp.asarray(GC_WEIGHT_PRESETS[policy], jnp.float32)


def _random_block_state(rng: np.random.Generator, k: int, b: int, g_max: int):
    """A random per-step selector input: exactly the fields
    ``_select_victim`` reads (duck-typed — the selector is a pure function
    of these arrays)."""
    return SimpleNamespace(
        state=jnp.asarray(
            rng.choice([FREE, OPEN, CLOSED], size=k).astype(np.int8)
        ),
        group_of=jnp.asarray(rng.integers(-1, g_max, size=k, dtype=np.int32)),
        live=jnp.asarray(rng.integers(0, b + 1, size=k, dtype=np.int32)),
        stamp=jnp.asarray(rng.integers(0, 10_000, size=k, dtype=np.int32)),
        erase_count=jnp.asarray(
            rng.integers(0, 500, size=k, dtype=np.int32)
        ),
        trim_dead=jnp.asarray(rng.integers(0, b + 1, size=k, dtype=np.int32)),
    )


class TestScoringOracle:
    """Per-step equivalence: on arbitrary block states, the scoring layer
    with legacy weights must pick the block the old argmin branch picked —
    including the first-index tie-break and the empty-candidate case."""

    @settings(max_examples=60, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_greedy_weights_pick_argmin_live(self, seed):
        rng = np.random.default_rng(seed)
        k, b, g_max = 64, 8, 4
        ctx = SimpleNamespace(geom=SimpleNamespace(pages_per_block=b))
        fake = _random_block_state(rng, k, b, g_max)
        g = int(rng.integers(0, g_max))
        victim, ok = S._select_victim(ctx, fake, g, _weights("greedy"))
        closed = (np.asarray(fake.state) == CLOSED) & (
            np.asarray(fake.group_of) == g
        )
        live = np.asarray(fake.live)
        # the legacy branch: argmin over live masked to INT_MAX elsewhere
        expect = int(np.argmin(np.where(closed, live, np.iinfo(np.int32).max)))
        assert int(victim) == expect, (seed, g)
        expect_ok = closed[expect] and live[expect] < b
        assert bool(ok) == expect_ok, (seed, g)

    @settings(max_examples=60, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_lru_weights_pick_argmin_stamp(self, seed):
        rng = np.random.default_rng(seed)
        k, b, g_max = 64, 8, 4
        ctx = SimpleNamespace(geom=SimpleNamespace(pages_per_block=b))
        fake = _random_block_state(rng, k, b, g_max)
        g = int(rng.integers(0, g_max))
        victim, ok = S._select_victim(ctx, fake, g, _weights("lru"))
        closed = (np.asarray(fake.state) == CLOSED) & (
            np.asarray(fake.group_of) == g
        )
        stamp = np.asarray(fake.stamp)
        expect = int(
            np.argmin(np.where(closed, stamp, np.iinfo(np.int32).max))
        )
        assert int(victim) == expect, (seed, g)
        # LRU (age-driven, γ > 0) may clean a fully-live block
        assert bool(ok) == bool(closed[expect]), (seed, g)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_mixed_weights_match_numpy_score(self, seed):
        """General weight points agree with a float32 numpy evaluation of
        the documented score (argmax, first-index ties, -inf masking)."""
        rng = np.random.default_rng(seed)
        k, b, g_max = 64, 8, 4
        ctx = SimpleNamespace(geom=SimpleNamespace(pages_per_block=b))
        fake = _random_block_state(rng, k, b, g_max)
        g = int(rng.integers(0, g_max))
        w = rng.uniform(0.0, 2.0, size=4).astype(np.float32)
        victim, _ = S._select_victim(ctx, fake, g, jnp.asarray(w))
        closed = (np.asarray(fake.state) == CLOSED) & (
            np.asarray(fake.group_of) == g
        )
        score = (
            w[0] * (b - np.asarray(fake.live)).astype(np.float32)
            - w[2] * np.asarray(fake.stamp).astype(np.float32)
            - w[1] * np.asarray(fake.erase_count).astype(np.float32)
            - w[3] * np.asarray(fake.trim_dead).astype(np.float32)
        ).astype(np.float32)
        expect = int(np.argmax(np.where(closed, score, -np.inf)))
        assert int(victim) == expect, (seed, g, w)


class TestScoringRunEquivalence:
    """Whole-run oracle: spelling the legacy policies as explicit weight
    overrides is bit-identical to the preset string (same traced values)."""

    @settings(max_examples=4, deadline=None)
    @given(
        st.sampled_from(["wolf", "wolf_lru", "fdp"]),
        st.integers(min_value=0, max_value=10_000),
    )
    def test_explicit_weights_bit_identical(self, manager, seed):
        mcfg = getattr(M, manager)()
        a, b_, g_, t_ = mcfg.gc_weights()
        explicit = dataclasses.replace(
            mcfg, gc_alpha=a, gc_beta=b_, gc_gamma=g_, gc_trim_penalty=t_
        )
        phase = W.two_modal(GEOM.lba_pages, 6_000)
        r1 = M.simulate(GEOM, mcfg, [phase], seed=seed)
        r2 = M.simulate(GEOM, explicit, [phase], seed=seed)
        np.testing.assert_array_equal(r1.app, r2.app)
        np.testing.assert_array_equal(r1.mig, r2.mig)
        for key, arr in r1.state.items():
            np.testing.assert_array_equal(
                np.asarray(arr), np.asarray(r2.state[key]),
                err_msg=f"state[{key}]",
            )


class TestEraseAccounting:
    def test_wear_counters_conserve_across_policies(self):
        phase = W.two_modal(GEOM.lba_pages, 10_000, p_hot=0.9, frac_hot=0.2)
        for mcfg in (M.wolf(), M.wolf_lru(), M.wolf_wear(), M.wolf_dynamic()):
            res = M.simulate(GEOM, mcfg, [phase], seed=11)
            ec = np.asarray(res.state["erase_count"], np.int64)
            assert (ec >= 0).all()
            assert ec.sum() == int(res.state["n_erase"]), mcfg.name
            assert int(res.state["erase_total"]) == ec.sum(), mcfg.name
            assert int(res.state["erase_sq_total"]) == int((ec * ec).sum())
            # pure-write stream: no trimmed-but-unerased slots anywhere
            assert not np.asarray(res.state["trim_dead"]).any(), mcfg.name
            failed = [
                k for k, ok in res.state.check_invariants().items()
                if not bool(ok)
            ]
            assert not failed, (mcfg.name, failed)

    @pytest.mark.trim
    def test_trim_dead_tracks_trims_and_clears_on_erase(self):
        phase = W.trimmed(W.two_modal(GEOM.lba_pages, 10_000), 0.3)
        res = M.simulate(GEOM, M.wolf(), [phase], seed=5)
        td = np.asarray(res.state["trim_dead"])
        fill = np.asarray(res.state["fill"])
        live = np.asarray(res.state["live"])
        state = np.asarray(res.state["state"])
        assert (td >= 0).all() and (td <= fill - live).all()
        assert not td[state == FREE].any(), "erase clears trim_dead"
        assert int(res.state["n_trim"]) > 0


class TestWearLeveling:
    def test_wear_preset_reduces_variance_vs_greedy(self):
        """The acceptance-bar comparison in miniature: the wear weight
        point must level erases ≥2× (variance) on a skewed workload."""
        from repro.core.fleet import DriveSpec, simulate_fleet

        phase = W.two_modal(GEOM.lba_pages, 20_000, p_hot=0.9, frac_hot=0.2)
        specs = [
            DriveSpec(M.wolf(), (phase,), seed=7, name="greedy"),
            DriveSpec(M.wolf_wear(), (phase,), seed=7, name="wear"),
        ]
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        var = fleet.wear_variance()
        assert var[1] < var[0] / 2.0, var
        imb = fleet.wear_imbalance()
        assert imb[1] < imb[0], imb
        # leveling must not be free lunch accounting: both drives did work
        assert np.all(fleet.wa_total >= 1.0)

    def test_wear_analytics_formulas(self):
        ec = jnp.asarray([4, 6, 2, 8], jnp.int32)
        var = wear_variance(jnp.sum(ec), jnp.sum(ec * ec), 4)
        assert float(var) == pytest.approx(np.var([4, 6, 2, 8]))
        imb = wear_imbalance(ec)
        assert float(imb) == pytest.approx(8 / 5)
        # zero-erase drive: imbalance degenerates to level (1.0)
        assert float(wear_imbalance(jnp.zeros(4, jnp.int32))) == 1.0
        host = lifetime_host_writes(
            n_blocks=4, pages_per_block=8, pe_cycles=1000.0,
            wa=jnp.asarray(2.0), imbalance=imb,
        )
        assert float(host) == pytest.approx(4 * 8 * 1000 / (2.0 * 8 / 5))
        dwpd = dwpd_from_lifetime(host, lba_pages=16, years=1.0)
        assert float(dwpd) == pytest.approx(float(host) / (16 * 365.0))
