"""Executor edge paths: device resolution, ragged padding, the compiled-
runner memo, fenced sub-batch resolution, and the persistent-cache guard.

These pin the failure-handling seams of ``fleet_exec`` that the happy-path
fleet suites never reach: the import-order device trap only warns when the
host-device flag was never set; ``pad_batch`` must be a no-op at pad=0 and
a pure row-0 replication otherwise; ``step_cache_clear`` must actually
force a recompile; a poisoned sub-batch must surface as a
``SubbatchResolutionError`` carrying its partition key and drive ids after
the healthy sub-batches resolved; and the on-disk compilation cache must
refuse to arm itself on a jaxlib/backend combo known to corrupt the heap.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet as FL
from repro.core import fleet_exec as fe
from repro.core import managers as M
from repro.core import workloads as W
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.ssd import Geometry

GEOM = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8, lba_pba=0.7)


@pytest.mark.mesh
class TestResolveDevices:
    def test_single_device_fast_paths(self):
        assert fe.resolve_devices(None) == 1
        assert fe.resolve_devices(1) == 1

    def test_auto_and_clamp(self):
        # conftest pins 2 virtual CPU devices before jax init
        n = len(jax.devices())
        assert n >= 2
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # the flag IS set: no warning
            assert fe.resolve_devices("auto") == n
        assert fe.resolve_devices(2) == 2
        assert fe.resolve_devices(99) == n
        assert fe.resolve_devices("2") == 2

    def test_auto_warns_on_unset_flag(self, monkeypatch):
        """jax initialized before --xla_force_host_platform_device_count:
        "auto" silently seeing 1 device is the trap — it must warn."""
        monkeypatch.setattr(fe, "host_device_flag", lambda: None)
        monkeypatch.setattr(fe.jax, "devices", lambda: ["cpu:0"])
        if jax.default_backend() != "cpu":  # pragma: no cover
            pytest.skip("import-order trap is CPU-specific")
        with pytest.warns(RuntimeWarning, match="single CPU device"):
            assert fe.resolve_devices("auto") == 1


@pytest.mark.mesh
class TestPadBatch:
    def test_pad_zero_is_identity(self):
        tree = {"a": jnp.arange(6).reshape(3, 2)}
        assert fe.pad_batch(tree, 0) is tree

    def test_pad_replicates_row_zero(self):
        tree = {
            "a": jnp.arange(6, dtype=jnp.int32).reshape(3, 2),
            "b": jnp.asarray([1.0, 2.0, 3.0]),
        }
        out = fe.pad_batch(tree, 2)
        assert out["a"].shape == (5, 2) and out["b"].shape == (5,)
        np.testing.assert_array_equal(out["a"][:3], tree["a"])
        np.testing.assert_array_equal(
            out["a"][3:], np.broadcast_to(np.asarray(tree["a"][0]), (2, 2))
        )
        np.testing.assert_array_equal(out["b"][3:], [1.0, 1.0])

    def test_single_drive_pads_to_full_width(self):
        """The 1-drive sub-batch on a d-device mesh: every filler lane is
        a copy of the one real drive."""
        tree = (jnp.ones((1, 4)), {"x": jnp.zeros((1,))})
        out = fe.pad_batch(tree, 3)
        assert out[0].shape == (4, 4)
        assert out[1]["x"].shape == (4,)
        np.testing.assert_array_equal(out[0], np.ones((4, 4)))


@pytest.mark.mesh
class TestStepCacheClear:
    def test_clear_between_geometry_changes(self):
        spec = [DriveSpec(M.wolf(), (W.two_modal(GEOM.lba_pages, 1_200),),
                          seed=0)]
        fe.step_cache_clear()
        assert fe.step_cache_stats().misses == 0
        simulate_fleet(GEOM, spec, sampler="numpy")
        s1 = fe.step_cache_stats()
        assert s1.misses >= 1
        # identical step structure: pure memo hit, no new compile
        simulate_fleet(GEOM, spec, sampler="numpy")
        s2 = fe.step_cache_stats()
        assert s2.misses == s1.misses
        assert s2.hits > s1.hits
        # a cleared memo must recompile even for the structure just run
        fe.step_cache_clear()
        s3 = fe.step_cache_stats()
        assert (s3.hits, s3.misses) == (0, 0)
        simulate_fleet(GEOM, spec, sampler="numpy")
        assert fe.step_cache_stats().misses >= 1
        # a geometry change is a new step structure: miss, not hit
        geom2 = dataclasses.replace(GEOM, blocks_per_lun=16)
        spec2 = [DriveSpec(M.wolf(),
                           (W.two_modal(geom2.lba_pages, 1_200),), seed=0)]
        before = fe.step_cache_stats()
        simulate_fleet(geom2, spec2, sampler="numpy")
        after = fe.step_cache_stats()
        assert after.misses > before.misses

    def test_stats_is_a_copy(self):
        snap = fe.step_cache_stats()
        snap.hits += 1000
        assert fe.step_cache_stats().hits != snap.hits or snap.hits == 1000


class _PoisonedOutput:
    """Stands in for a sub-batch's device outputs whose resolution blows
    up (OOM, poisoned buffer): any attempt to unpack it raises."""

    def __iter__(self):
        raise RuntimeError("poisoned device buffer")


@pytest.mark.fault
class TestSubbatchResolution:
    def test_poisoned_subbatch_reports_context(self, monkeypatch):
        """One bad sub-batch must not orphan the others: the error names
        the failed sub-batch's partition key, drive ids, and labels, and
        is raised only after the healthy sub-batch resolved."""
        lba, n = GEOM.lba_pages, 1_200
        specs = [
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1, name="ok0"),
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=2, name="ok1"),
            # the bloom drive lands in its own partition — that one dies
            DriveSpec(M.wolf_dynamic(), (W.tpcc_like(lba, n),), seed=3,
                      name="doomed"),
        ]
        real_runner = FL.subbatch_runner
        resolved_ctxs = []

        def fake_runner(ctx, n_total, on_device, d):
            runner = real_runner(ctx, n_total, on_device, d)

            def wrapped(*args):
                out = runner(*args)
                resolved_ctxs.append(ctx)
                if ctx.use_bloom:
                    return _PoisonedOutput()
                return out

            return wrapped

        monkeypatch.setattr(FL, "subbatch_runner", fake_runner)
        with pytest.raises(fe.SubbatchResolutionError) as ei:
            simulate_fleet(GEOM, specs, sampler="numpy")
        err = ei.value
        assert err.n_subbatches == 2
        assert len(resolved_ctxs) == 2, "healthy dispatch was orphaned"
        (failure,) = err.failures
        assert failure.drive_ids == (2,)
        assert failure.labels == ("doomed",)
        assert isinstance(failure.error, RuntimeError)
        assert isinstance(failure.part_key, tuple)
        msg = str(err)
        assert "1/2" in msg and "doomed" in msg
        assert "poisoned device buffer" in msg


@pytest.mark.fault
class TestPersistentCacheGuard:
    """enable_persistent_compilation_cache must refuse to arm the on-disk
    cache on a jaxlib/backend combo known to corrupt the heap (see the
    hazard note on the function), unless explicitly forced."""

    def _arm(self, monkeypatch):
        if jax.default_backend() != "cpu":  # pragma: no cover
            pytest.skip("the known-bad combos are all XLA:CPU")
        import jaxlib

        # pin the CURRENT jaxlib as known-bad so the test is meaningful
        # even after a toolchain bump
        monkeypatch.setattr(
            fe, "_CACHE_BAD_JAXLIB_CPU",
            fe._CACHE_BAD_JAXLIB_CPU + (jaxlib.__version__,),
        )
        monkeypatch.setattr(fe, "_PERSISTENT_WIRED", False)
        monkeypatch.delenv("REPRO_JAX_CACHE_FORCE", raising=False)
        calls = []
        monkeypatch.setattr(
            fe.jax.config, "update", lambda *a: calls.append(a)
        )
        return calls

    def test_container_combo_is_flagged(self):
        """The pinned container toolchain (jaxlib 0.4.36/0.4.37 on
        XLA:CPU) is exactly the bisected combo: the hazard fires here."""
        import jaxlib

        if (jax.default_backend() != "cpu"
                or jaxlib.__version__ not in fe._CACHE_BAD_JAXLIB_CPU):
            pytest.skip("not a known-bad jaxlib/backend combo")
        hazard = fe._persistent_cache_hazard()
        assert hazard is not None and "heap" in hazard

    def test_refuses_on_known_bad_combo(self, monkeypatch, tmp_path):
        calls = self._arm(monkeypatch)
        with pytest.warns(RuntimeWarning, match="refusing to enable"):
            out = fe.enable_persistent_compilation_cache(str(tmp_path))
        assert out == str(tmp_path)  # path still reported, never wired
        assert calls == []
        assert fe._PERSISTENT_WIRED is False

    def test_force_override_wires(self, monkeypatch, tmp_path):
        calls = self._arm(monkeypatch)
        monkeypatch.setenv("REPRO_JAX_CACHE_FORCE", "1")
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            out = fe.enable_persistent_compilation_cache(str(tmp_path))
        assert out == str(tmp_path)
        assert ("jax_compilation_cache_dir", str(tmp_path)) in calls
        assert fe._PERSISTENT_WIRED is True

    def test_clean_combo_wires(self, monkeypatch, tmp_path):
        calls = self._arm(monkeypatch)
        monkeypatch.setattr(fe, "_persistent_cache_hazard", lambda: None)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            fe.enable_persistent_compilation_cache(str(tmp_path))
        assert ("jax_compilation_cache_dir", str(tmp_path)) in calls
        assert fe._PERSISTENT_WIRED is True
