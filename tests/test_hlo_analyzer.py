"""Validate the trip-count-aware HLO analyzer against ground truth.

Strategy: on loop-free jitted programs, XLA's own cost_analysis IS correct —
the analyzer must agree on FLOPs. On scanned programs, the analyzer must
report ≈ trip_count × the unrolled per-iteration cost (which cost_analysis
misses — the reason the analyzer exists)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo import analyze_hlo, collective_stats, xla_cost_analysis


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


class TestFlops:
    def test_single_matmul_exact(self):
        m, k, n = 128, 256, 512
        compiled = _compile(
            lambda a, b: a @ b,
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
        )
        res = analyze_hlo(compiled.as_text())
        assert res["flops"] == pytest.approx(2 * m * k * n, rel=0.01)

    def test_agrees_with_cost_analysis_loop_free(self):
        def fn(a, b, c):
            return (a @ b) @ c

        compiled = _compile(
            fn,
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 32), jnp.float32),
        )
        res = analyze_hlo(compiled.as_text())
        cost = xla_cost_analysis(compiled)
        xla_flops = float(cost.get("flops", 0.0))
        if xla_flops > 0:
            assert res["flops"] == pytest.approx(xla_flops, rel=0.05)

    def test_scan_multiplies_by_trip_count(self):
        n_steps, m = 24, 128

        def fn(w, x):
            def body(x, _):
                return jnp.tanh(x @ w), None

            y, _ = jax.lax.scan(body, x, None, length=n_steps)
            return y

        compiled = _compile(
            fn,
            jax.ShapeDtypeStruct((m, m), jnp.float32),
            jax.ShapeDtypeStruct((m, m), jnp.float32),
        )
        res = analyze_hlo(compiled.as_text())
        expected = n_steps * 2 * m**3
        assert res["flops"] == pytest.approx(expected, rel=0.05), (
            res["flops"], expected,
        )
        # XLA's own analysis counts the body ONCE — the whole point:
        xla_flops = float(xla_cost_analysis(compiled).get("flops", 0.0))
        if xla_flops > 0:
            assert xla_flops < expected / (n_steps / 2)

    def test_nested_scan(self):
        outer, inner, m = 4, 6, 64

        def fn(w, x):
            def inner_body(x, _):
                return x @ w, None

            def outer_body(x, _):
                y, _ = jax.lax.scan(inner_body, x, None, length=inner)
                return y, None

            y, _ = jax.lax.scan(outer_body, x, None, length=outer)
            return y

        compiled = _compile(
            fn,
            jax.ShapeDtypeStruct((m, m), jnp.float32),
            jax.ShapeDtypeStruct((m, m), jnp.float32),
        )
        res = analyze_hlo(compiled.as_text())
        assert res["flops"] == pytest.approx(outer * inner * 2 * m**3, rel=0.05)


class TestBytes:
    def test_elementwise_bytes_reasonable(self):
        n = 1 << 20

        def fn(a, b):
            return a * 2.0 + b

        compiled = _compile(
            fn,
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        )
        res = analyze_hlo(compiled.as_text())
        ideal = 3 * n * 4  # read a, read b, write out
        assert ideal * 0.5 <= res["bytes"] <= ideal * 3

    def test_convert_is_free_and_traced_through(self):
        # bf16 stored value feeding an f32 dot: traffic = bf16 bytes, and the
        # convert itself contributes nothing.
        m = 256

        def fn(a, b):
            return a.astype(jnp.float32) @ b

        compiled = _compile(
            fn,
            jax.ShapeDtypeStruct((m, m), jnp.bfloat16),
            jax.ShapeDtypeStruct((m, m), jnp.float32),
        )
        res = analyze_hlo(compiled.as_text())
        # a as bf16 (2B) + b f32 (4B) + out f32 (4B), allow fusion slop
        ideal = m * m * (2 + 4 + 4)
        assert res["bytes"] <= ideal * 2.5


class TestCollectives:
    def test_no_collectives_single_device(self):
        compiled = _compile(
            lambda a: a + 1.0, jax.ShapeDtypeStruct((128,), jnp.float32)
        )
        stats = collective_stats(compiled.as_text())
        assert stats["total_bytes"] == 0
