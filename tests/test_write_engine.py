"""Fast-path write-engine equivalence suite (the tentpole's acceptance bar).

The split step (``SimContext.fast_path=True``: O(1) scalar predicates
routing steady-state writes around the GC/valve/movement/interval
machinery, with the fused ``kernels/write_path`` append) must be
elementwise-identical to the seed-shaped single-path step retained as
``fast_path=False`` — final state, counters, and WA curves — across
manager presets, under both jit (``managers.simulate``) and vmap
(``simulate_fleet``), and against the ``gc_impl="reference"`` oracle so the
whole new engine is anchored to the seed semantics end-to-end.

Also here: the strided-trace contract (``trace_every=k`` samples the dense
cumulative counters exactly) and the O(1)-accounting invariant property
test (``SimState.check_invariants`` after random write segments under both
GC drains).
"""

import dataclasses
import inspect

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import managers as M
from repro.core import simulator as S
from repro.core import workloads as W
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.ssd import Geometry, ManagerConfig, assert_invariants

GEOM = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8, lba_pba=0.7)
N_WRITES = 6_000

_MANAGERS = {
    "wolf": M.wolf,            # closed-form alloc, greedy GC, static TD
    "wolf_lru": M.wolf_lru,    # LRU GC under movement ops
    "fdp": M.fdp,              # assumed alloc, LRU GC, fdp demotion
    "wolf_dynamic": M.wolf_dynamic,  # bloom detector + dynamic groups
    "single": M.single_group,  # one group, size alloc
}


def _phases(workload: str, rng: np.random.Generator):
    lba = GEOM.lba_pages
    if workload == "two_modal":
        return [W.two_modal(
            lba, N_WRITES,
            p_hot=float(rng.uniform(0.6, 0.95)),
            frac_hot=float(rng.uniform(0.2, 0.8)),
        )]
    if workload == "tpcc":
        return [W.tpcc_like(lba, N_WRITES)]
    return list(W.swap_phases(lba, N_WRITES // 2))


def _assert_identical(a, b, label: str):
    np.testing.assert_array_equal(a.app, b.app, err_msg=f"{label}: app")
    np.testing.assert_array_equal(a.mig, b.mig, err_msg=f"{label}: mig")
    assert int(a.state["n_dropped"]) == 0, f"{label}: writes dropped"
    for key, arr in a.state.items():
        np.testing.assert_array_equal(
            np.asarray(arr), np.asarray(b.state[key]),
            err_msg=f"{label}: state[{key}]",
        )
    np.testing.assert_array_equal(
        a.wa_curve(1000), b.wa_curve(1000), err_msg=f"{label}: wa_curve"
    )


class TestStepEquivalence:
    """Split engine vs the seed-shaped oracle step."""

    @settings(max_examples=6, deadline=None)
    @given(
        st.sampled_from(sorted(_MANAGERS)),
        st.sampled_from(["two_modal", "tpcc", "swap"]),
        st.integers(min_value=0, max_value=10_000),
    )
    def test_split_matches_oracle_under_jit(self, manager, workload, seed):
        mcfg = _MANAGERS[manager]()
        phases = _phases(workload, np.random.default_rng(seed))
        split = M.simulate(GEOM, mcfg, phases, seed=seed)  # fast_path=True
        oracle = M.simulate(
            GEOM, mcfg, phases, seed=seed,
            fast_path=False, gc_impl="reference",
        )
        _assert_identical(split, oracle, f"{manager}/{workload}#{seed}")

    def test_split_matches_oracle_under_vmap(self):
        """Whole mixed fleet (all four step-structure partitions, a §5.1
        sweep drive, multi-phase swap) under both engines."""
        lba, n = GEOM.lba_pages, N_WRITES
        specs = [
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1),
            DriveSpec(M.fdp(), (W.two_modal(lba, n),), seed=2),
            DriveSpec(M.single_group(), (W.tpcc_like(lba, n),), seed=3),
            DriveSpec(M.wolf(ewma_a=0.6, interval_frac=0.05),
                      (W.two_modal(lba, n),), seed=4),
            DriveSpec(M.wolf(), tuple(W.swap_phases(lba, n // 2)), seed=5),
            DriveSpec(M.wolf_dynamic(), (W.tpcc_like(lba, n),), seed=6),
        ]
        split = simulate_fleet(GEOM, specs, sampler="numpy", fast_path=True)
        oracle = simulate_fleet(
            GEOM, specs, sampler="numpy",
            fast_path=False, gc_impl="reference",
        )
        np.testing.assert_array_equal(split.app, oracle.app)
        np.testing.assert_array_equal(split.mig, oracle.mig)
        for i, s in enumerate(specs):
            for key, arr in split.state(i).items():
                np.testing.assert_array_equal(
                    np.asarray(arr), np.asarray(oracle.state(i)[key]),
                    err_msg=f"{s.label}: state[{key}]",
                )
        np.testing.assert_array_equal(
            split.wa_curves(1000), oracle.wa_curves(1000)
        )


@pytest.mark.trim
class TestOpStreamEquivalence:
    """An all-WRITE op stream must reproduce the pure-write engine
    bit-identically — state, counters, WA curves — under jit and vmap
    (the op-stream tentpole's baseline-compatibility bar). With the host
    sampler, Phase.sample_ops consumes exactly the draws Phase.sample
    would on a pure-write phase, so the event sequences are identical and
    any divergence is the op engine's fault."""

    @settings(max_examples=6, deadline=None)
    @given(
        st.sampled_from(sorted(_MANAGERS)),
        st.sampled_from(["two_modal", "tpcc", "swap"]),
        st.integers(min_value=0, max_value=10_000),
    )
    def test_all_write_ops_match_write_engine_under_jit(
        self, manager, workload, seed
    ):
        mcfg = _MANAGERS[manager]()
        phases = _phases(workload, np.random.default_rng(seed))
        base = M.simulate(GEOM, mcfg, phases, seed=seed)
        ops = M.simulate(GEOM, mcfg, phases, seed=seed, ops_stream=True)
        _assert_identical(ops, base, f"ops:{manager}/{workload}#{seed}")
        assert int(ops.state["n_trim"]) == 0

    def test_all_write_ops_match_write_engine_under_vmap(self):
        """Whole mixed fleet (every step-structure partition forced onto
        the op engine) vs the pure-write fleet."""
        lba, n = GEOM.lba_pages, N_WRITES
        specs = [
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1),
            DriveSpec(M.fdp(), (W.two_modal(lba, n),), seed=2),
            DriveSpec(M.single_group(), (W.tpcc_like(lba, n),), seed=3),
            DriveSpec(M.wolf(ewma_a=0.6, interval_frac=0.05),
                      (W.two_modal(lba, n),), seed=4),
            DriveSpec(M.wolf(), tuple(W.swap_phases(lba, n // 2)), seed=5),
            DriveSpec(M.wolf_dynamic(), (W.tpcc_like(lba, n),), seed=6),
        ]
        base = simulate_fleet(GEOM, specs, sampler="numpy")
        ops = simulate_fleet(GEOM, specs, sampler="numpy", ops_stream=True)
        np.testing.assert_array_equal(ops.app, base.app)
        np.testing.assert_array_equal(ops.mig, base.mig)
        for i, s in enumerate(specs):
            for key, arr in ops.state(i).items():
                np.testing.assert_array_equal(
                    np.asarray(arr), np.asarray(base.state(i)[key]),
                    err_msg=f"{s.label}: state[{key}]",
                )
        np.testing.assert_array_equal(
            ops.wa_curves(1000), base.wa_curves(1000)
        )

    def test_ops_engine_split_matches_oracle(self):
        """Both step engines agree on an op stream WITH trims (jit)."""
        phases = [W.trimmed(W.two_modal(GEOM.lba_pages, N_WRITES), 0.25)]
        for manager in ("wolf", "fdp", "wolf_dynamic", "single"):
            mcfg = _MANAGERS[manager]()
            split = M.simulate(GEOM, mcfg, phases, seed=13)
            oracle = M.simulate(GEOM, mcfg, phases, seed=13,
                                fast_path=False, gc_impl="reference")
            _assert_identical(split, oracle, f"trim:{manager}")


class TestStridedTrace:
    """trace_every=k cumulative counters == dense trace at steps k·j."""

    @pytest.mark.parametrize("k", [10, 250, 1500])
    def test_jit_stride_samples_dense(self, k):
        phases = [W.two_modal(GEOM.lba_pages, N_WRITES, p_hot=0.9,
                              frac_hot=0.3)]
        dense = M.simulate(GEOM, M.wolf(), phases, seed=7)
        strided = M.simulate(GEOM, M.wolf(), phases, seed=7, trace_every=k)
        assert len(strided.app) == N_WRITES // k
        np.testing.assert_array_equal(
            np.asarray(dense.app)[k - 1 :: k], strided.app
        )
        np.testing.assert_array_equal(
            np.asarray(dense.mig)[k - 1 :: k], strided.mig
        )
        # stride-aware windowed WA agrees elementwise with the dense curve
        if 3000 % k == 0:
            np.testing.assert_array_equal(
                dense.wa_curve(3000), strided.wa_curve(3000)
            )
        assert strided.wa_total == dense.wa_total

    def test_vmap_stride_samples_dense(self):
        lba, n = GEOM.lba_pages, N_WRITES
        specs = [
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1),
            DriveSpec(M.single_group(), (W.uniform(lba, n),), seed=2),
        ]
        dense = simulate_fleet(GEOM, specs, sampler="numpy")
        strided = simulate_fleet(
            GEOM, specs, sampler="numpy", trace_every=500
        )
        np.testing.assert_array_equal(dense.app[:, 499::500], strided.app)
        np.testing.assert_array_equal(dense.mig[:, 499::500], strided.mig)
        np.testing.assert_array_equal(
            dense.wa_curves(1000), strided.wa_curves(1000)
        )
        for i in range(len(specs)):
            for key, arr in dense.state(i).items():
                np.testing.assert_array_equal(
                    np.asarray(arr), np.asarray(strided.state(i)[key]),
                    err_msg=f"state[{key}]",
                )

    def test_unroll_is_semantics_free(self):
        phases = [W.tpcc_like(GEOM.lba_pages, 3_000)]
        base = M.simulate(GEOM, M.wolf(), phases, seed=9)
        unrolled = M.simulate(
            GEOM, M.wolf(), phases, seed=9, trace_every=100, unroll=4
        )
        np.testing.assert_array_equal(
            np.asarray(base.app)[99::100], unrolled.app
        )
        for key, arr in base.state.items():
            np.testing.assert_array_equal(
                np.asarray(arr), np.asarray(unrolled.state[key]),
                err_msg=f"state[{key}]",
            )

    def test_stride_must_divide_segment(self):
        phases = [W.uniform(GEOM.lba_pages, 1_000)]
        with pytest.raises(AssertionError):
            M.simulate(GEOM, M.wolf(), phases, seed=0, trace_every=300)


class TestInvariantChecker:
    """SimState.check_invariants: the debug cross-check of the carried
    O(1) accounting (satellite task)."""

    @settings(max_examples=6, deadline=None)
    @given(
        st.sampled_from(["wolf", "fdp", "wolf_dynamic", "single"]),
        st.sampled_from(["two_modal", "tpcc"]),
        st.integers(min_value=0, max_value=10_000),
        st.sampled_from(["bulk", "reference"]),
        st.sampled_from([0.0, 0.2, 0.5]),
    )
    def test_invariants_after_random_segments(
        self, manager, workload, seed, gc_impl, trim_frac
    ):
        mcfg = _MANAGERS[manager]()
        rng = np.random.default_rng(seed)
        phases = _phases(workload, rng)
        if trim_frac:  # random interleaved TRIMs through the op engine
            phases = [W.trimmed(ph, trim_frac) for ph in phases]
        # split the stream into irregular segments: the checker must hold
        # at every re-entry point, not only at the end of a clean run
        res = M.simulate(GEOM, mcfg, phases, seed=seed, gc_impl=gc_impl)
        assert_invariants(
            res.state, f"{manager}/{workload}/{gc_impl}/t={trim_frac}"
        )

    def test_checker_catches_drift(self):
        import jax.numpy as jnp

        phases = [W.two_modal(GEOM.lba_pages, 2_000)]
        res = M.simulate(GEOM, M.wolf(), phases, seed=0)
        good = res.state
        assert all(bool(v) for v in good.check_invariants().values())
        bad = good.replace(free_blocks=good.free_blocks + 1)
        assert not bool(bad.check_invariants()["free_blocks"])
        bad = good.replace(grp_surplus=good.grp_surplus.at[0].add(1))
        assert not bool(bad.check_invariants()["grp_surplus"])
        bad = good.replace(mapped_pages=good.mapped_pages - 1)
        assert not bool(bad.check_invariants()["mapped_pages"])
        bad = good.replace(grp_live=good.grp_live.at[0].add(1))
        assert not bool(bad.check_invariants()["grp_live"])
        bad = good.replace(
            page_map=good.page_map.at[1].set(good.page_map[0])
        )
        assert not bool(bad.check_invariants()["page_map_injective"])
        with pytest.raises(AssertionError, match="free_blocks"):
            assert_invariants(
                good.replace(free_blocks=jnp.asarray(-1)), "drift"
            )


class TestNeighborReductions:
    """The reduction-based hotter/colder neighbor finds must equal the
    argsort oracle (_sgv_neighbors) on arbitrary group stats."""

    @settings(max_examples=30, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_matches_argsort_oracle(self, seed):
        import jax.numpy as jnp

        rng = np.random.default_rng(seed)
        g_max = int(rng.integers(2, 13))
        active = rng.random(g_max) < 0.8
        if not active.any():
            active[0] = True
        grp_p = np.where(active, rng.random(g_max).astype(np.float32), 0.0)
        # force ties sometimes
        if g_max > 2 and rng.random() < 0.5:
            grp_p[1] = grp_p[0]
        grp_size = np.where(
            active, rng.integers(1, 50, g_max), 0
        ).astype(np.int32)
        hr = jnp.where(
            jnp.asarray(active),
            jnp.asarray(grp_p) / jnp.maximum(
                jnp.asarray(grp_size, jnp.float32), 1.0
            ),
            -1.0,
        )

        class FakeState:
            grp_active = jnp.asarray(active)

        fake = FakeState()
        g_mx = hr.shape[0]
        order = np.argsort(-np.asarray(hr), kind="stable")
        rank = np.zeros(g_mx, np.int32)
        rank[order] = np.arange(g_mx)
        n_active = int(active.sum())
        for g in range(g_max):
            if not active[g]:
                continue
            up = order[np.clip(rank[g] - 1, 0, n_active - 1)]
            dn = order[np.clip(rank[g] + 1, 0, n_active - 1)]
            got_up = int(S._neighbor_hotter(hr, fake.grp_active, g))
            got_dn = int(S._neighbor_colder(hr, fake.grp_active, g))
            assert got_up == up, (seed, g, np.asarray(hr), active)
            assert got_dn == dn, (seed, g, np.asarray(hr), active)


class TestEngineStructure:
    def test_default_context_uses_split_engine(self):
        ctx = S.SimContext(GEOM, M.wolf(), 2)
        assert ctx.fast_path and ctx.trace_every == 1

    def test_no_full_reduction_in_step_predicates(self):
        """Acceptance bar: per-write predicates are O(1) reads of the
        carried accounting — no `state == FREE` reduction survives in the
        step builder or the tail (only victim selection and the drains'
        free-rank computation may reduce over blocks)."""
        for fn in (S.make_step, S._step_tail):
            src = inspect.getsource(fn)
            assert "state == FREE" not in src, fn.__name__
            assert "free_blocks" in src, fn.__name__

    def test_valve_and_bloom_bounds_are_config(self):
        mcfg = ManagerConfig()
        assert mcfg.valve_max_tries == 4  # seed default
        assert mcfg.bloom_rotate_min_writes == 64  # seed default
        # and they are honored as overrides
        m2 = dataclasses.replace(mcfg, valve_max_tries=2,
                                 bloom_rotate_min_writes=128)
        assert m2.valve_max_tries == 2
        assert m2.bloom_rotate_min_writes == 128
        src = inspect.getsource(S._step_tail)
        assert "valve_max_tries" in src and "tries < 4" not in src
        src = inspect.getsource(S._bloom_update)
        assert "bloom_rotate_min_writes" in src

    def test_fast_path_has_no_gc_machinery(self):
        """The lean branch carries no GC/valve/interval calls."""
        src = inspect.getsource(S.make_step)
        after_def = src.split("def fast_path(st):")[1]
        delim = "out = jax.lax.cond"
        assert delim in after_def, "split_step cond structure changed"
        fast = after_def.split(delim)[0]
        for marker in ("_gc_one", "while_loop", "_interval_update"):
            assert marker not in fast, marker
