"""Suite-wide wiring: offline hypothesis fallback.

The container has no network access; when the real ``hypothesis`` package is
absent, install the deterministic shim from ``_hypothesis_compat`` before
any test module runs ``from hypothesis import ...``.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

try:
    import hypothesis  # noqa: F401  (prefer the real package)
except ImportError:
    import _hypothesis_compat

    _hypothesis_compat.install()
