"""Suite-wide wiring: virtual host devices + offline hypothesis fallback.

Two virtual CPU devices are pinned BEFORE any test module can import jax
(the count is locked at backend init — see repro.utils.hostdev), so the
`mesh`-marked multi-device fleet tests (tests/test_fleet_mesh.py) always
have a real 2-device mesh to shard over; an explicit
``--xla_force_host_platform_device_count`` already in ``XLA_FLAGS`` wins.
Single-device tests are unaffected: computations run on device 0 unless
explicitly sharded.

The container has no network access; when the real ``hypothesis`` package is
absent, install the deterministic shim from ``_hypothesis_compat`` before
any test module runs ``from hypothesis import ...``.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

from repro.utils.hostdev import force_host_device_count  # noqa: E402

force_host_device_count(2)

try:
    import hypothesis  # noqa: F401  (prefer the real package)
except ImportError:
    import _hypothesis_compat

    _hypothesis_compat.install()
