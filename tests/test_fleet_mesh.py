"""Multi-device fleet executor tests (the `mesh` lane).

The shard_map drive-axis executor must be a pure scheduling change: a fleet
sharded over ≥2 devices is bit-identical (traces, final states, WA curves)
to the single-device vmap path, ragged sub-batches use every requested
device via inert filler padding, and revisiting a step structure hits the
compiled-runner memo instead of recompiling. tests/conftest.py pins 2
virtual CPU devices before jax initializes, so these run everywhere.
"""

import jax
import numpy as np
import pytest

from repro.core import fleet_exec as FX
from repro.core import managers as M
from repro.core import workloads as W
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.ssd import Geometry

GEOM = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8, lba_pba=0.7)
N_WRITES = 4_000

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs ≥2 jax devices (tests/conftest.py pins 2 on CPU)",
)


def _mixed_specs(lba, n):
    """Mixed managers × workloads chosen to exercise every padding case on
    a 2-device mesh: the wolf-structure sub-batch has 3 drives (ragged —
    pad 1), the single-group and trim sub-batches have 1 drive each
    (smaller than the mesh — pad up to it)."""
    return [
        DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1),
        DriveSpec(M.wolf(), (W.uniform(lba, n),), seed=2),
        DriveSpec(M.wolf_lru(), (W.tpcc_like(lba, n),), seed=3),
        DriveSpec(M.single_group(), (W.uniform(lba, n),), seed=4),
        # op-stream (TRIM) sub-batch: WRITE/TRIM dispatch step under shard_map
        DriveSpec(M.wolf(), (W.tpcc_churn(lba, n),), seed=5),
    ]


@pytest.mark.mesh
@needs_mesh
class TestMeshEquivalence:
    @pytest.fixture(scope="class")
    def fleets(self):
        specs = _mixed_specs(GEOM.lba_pages, N_WRITES)
        one = simulate_fleet(GEOM, specs, sampler="numpy", devices=None)
        two = simulate_fleet(GEOM, specs, sampler="numpy", devices=2)
        return specs, one, two

    def test_traces_bit_identical(self, fleets):
        specs, one, two = fleets
        np.testing.assert_array_equal(one.app, two.app)
        np.testing.assert_array_equal(one.mig, two.mig)

    def test_final_states_bit_identical(self, fleets):
        specs, one, two = fleets
        for i, s in enumerate(specs):
            st1, st2 = one.state(i), two.state(i)
            for key, a in st1.items():
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(st2[key]),
                    err_msg=f"{s.label}: state[{key}] diverged across meshes",
                )

    def test_wa_curves_bit_identical(self, fleets):
        _, one, two = fleets
        np.testing.assert_array_equal(
            one.wa_curves(window=1000), two.wa_curves(window=1000)
        )

    def test_ragged_subbatches_use_all_devices(self, fleets):
        _, one, two = fleets
        # single-device path: everything on 1 device, no padding
        assert one.devices_used == 1
        assert all(m["padding"] == 0 for m in one.exec_meta)
        # mesh path: every sub-batch shards over min(2, drives) devices —
        # the old divisor clamp would have collapsed the ragged 3-drive
        # sub-batch to 1 device
        assert two.devices_used == 2
        by_drives = {m["drives"]: m for m in two.exec_meta}
        assert by_drives[3]["devices"] == 2 and by_drives[3]["padding"] == 1
        assert all(
            m["devices"] == min(2, m["drives"]) for m in two.exec_meta
        )

    def test_device_sampler_bit_identical_across_meshes(self):
        # streams are keyed by seed alone, so the on-device sampler must
        # also be invariant to the mesh layout
        lba = GEOM.lba_pages
        specs = [
            DriveSpec(M.wolf(), (W.two_modal(lba, N_WRITES),), seed=7),
            DriveSpec(M.wolf(), (W.uniform(lba, N_WRITES),), seed=8),
        ]
        one = simulate_fleet(GEOM, specs, sampler="jax", devices=None)
        two = simulate_fleet(GEOM, specs, sampler="jax", devices=2)
        np.testing.assert_array_equal(one.app, two.app)
        np.testing.assert_array_equal(one.mig, two.mig)
        for i in range(len(specs)):
            for key, a in one.state(i).items():
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(two.state(i)[key]), err_msg=key
                )


@pytest.mark.mesh
@needs_mesh
def test_step_cache_hits_across_two_grid_sweep():
    """A sweep that revisits a step structure (same partitions/geometry/
    scan length, new seeds) must reuse every compiled runner: zero new
    misses, one hit per dispatched sub-batch."""
    lba, n = GEOM.lba_pages, 2_000

    def grid(seeds):
        return [
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=seeds[0]),
            DriveSpec(M.wolf(), (W.uniform(lba, n),), seed=seeds[1]),
            DriveSpec(M.single_group(), (W.uniform(lba, n),), seed=seeds[2]),
        ]

    simulate_fleet(GEOM, grid((0, 1, 2)), sampler="numpy", devices=2)
    before = FX.step_cache_stats()
    simulate_fleet(GEOM, grid((3, 4, 5)), sampler="numpy", devices=2)
    after = FX.step_cache_stats()
    assert after.misses == before.misses, "same-structure grid recompiled"
    # two sub-batches (wolf-structure, single-structure) per grid
    assert after.hits == before.hits + 2
