"""Minimal offline stand-in for ``hypothesis`` (property-based testing).

The test container has no network, so ``pip install hypothesis`` is not an
option. This shim implements the tiny slice of the hypothesis API the suite
uses — ``given``, ``settings``, and the ``integers`` / ``floats`` /
``booleans`` / ``sampled_from`` strategies — backed by seeded deterministic
draws (seed = hash of the test's qualname + example index), so failures are
reproducible run to run. There is no shrinking and no adaptive search; this
trades hypothesis's guided exploration for a fixed quasi-random sweep of
``max_examples`` points, which is what the suite's @settings budgets assume.

``conftest.py`` installs this module under ``sys.modules["hypothesis"]``
only when the real package is not importable — prefer real hypothesis.
"""

from __future__ import annotations

import functools
import hashlib
import inspect
import sys
import types

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    def __init__(self, draw_fn, label: str):
        self._draw_fn = draw_fn
        self.label = label

    def draw(self, rng: np.random.Generator):
        return self._draw_fn(rng)

    def __repr__(self):
        return f"SearchStrategy({self.label})"


def integers(min_value=None, max_value=None) -> SearchStrategy:
    lo = -(2 ** 15) if min_value is None else int(min_value)
    hi = (2 ** 15) if max_value is None else int(max_value)
    return SearchStrategy(
        lambda rng: int(rng.integers(lo, hi + 1)), f"integers({lo}, {hi})"
    )


def floats(
    min_value=None,
    max_value=None,
    allow_nan=False,
    allow_infinity=False,
    width=64,
) -> SearchStrategy:
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)
    return SearchStrategy(
        lambda rng: float(rng.uniform(lo, hi)), f"floats({lo}, {hi})"
    )


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(len(elements)))],
        f"sampled_from(<{len(elements)}>)",
    )


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, "just")


def one_of(*strategies) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: strategies[int(rng.integers(len(strategies)))].draw(rng),
        "one_of",
    )


def tuples(*strategies) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.draw(rng) for s in strategies), "tuples"
    )


def lists(elements, min_size=0, max_size=8) -> SearchStrategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]

    return SearchStrategy(draw, "lists")


class settings:
    """Decorator recording the per-test example budget (deadline ignored)."""

    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        self.max_examples = int(max_examples)
        self.deadline = deadline

    def __call__(self, fn):
        fn._hyp_settings = self
        return fn


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    all = classmethod(lambda cls: [])


def assume(condition) -> bool:
    """Degraded assume: skip the example by raising a private marker."""
    if not condition:
        raise _AssumptionFailed
    return True


class _AssumptionFailed(Exception):
    pass


def _base_seed(qualname: str) -> int:
    digest = hashlib.sha256(qualname.encode()).digest()
    return int.from_bytes(digest[:8], "big") % (2 ** 63)


def given(*strategies, **kw_strategies):
    def decorate(fn):
        inherited = getattr(fn, "_hyp_settings", None)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_hyp_settings", None) or inherited
            n = cfg.max_examples if cfg else DEFAULT_MAX_EXAMPLES
            seed0 = _base_seed(fn.__qualname__)
            for i in range(n):
                rng = np.random.default_rng((seed0 + i) % (2 ** 63))
                drawn = [s.draw(rng) for s in strategies]
                kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **kw)
                except _AssumptionFailed:
                    continue
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example #{i}: args={drawn} kwargs={kw}"
                    ) from exc
            return None

        # pytest resolves fixture names from the signature; strip the
        # strategy-bound (rightmost positional + keyword) parameters so it
        # does not try to inject them as fixtures.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = params[: len(params) - len(strategies)] if strategies else params
        keep = [p for p in keep if p.name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=keep)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        if inherited is not None:
            wrapper._hyp_settings = inherited
        return wrapper

    return decorate


def install():
    """Register this shim as ``hypothesis`` + ``hypothesis.strategies``."""
    hyp = types.ModuleType("hypothesis")
    hyp.__version__ = "0.0.offline-shim"
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    strat = types.ModuleType("hypothesis.strategies")
    for name in (
        "integers", "floats", "booleans", "sampled_from", "just", "one_of",
        "tuples", "lists", "SearchStrategy",
    ):
        setattr(strat, name, globals()[name])
    hyp.strategies = strat
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
    return hyp
