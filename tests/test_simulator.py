"""Simulator tests: equilibrium (Fig. 1), policy comparisons (Fig. 2),
Wolf-vs-FDP adaptation (Figs. 6–8), and state invariants (hypothesis)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.analytics import wa_from_op_ratio
from repro.core.ssd import Geometry

GEOM = Geometry(n_luns=8, blocks_per_lun=64, pages_per_block=16, lba_pba=0.7)


def _expected_wa(geom):
    s = geom.lba_pages
    op_eff = geom.pba_pages - 3 * geom.pages_per_block - s
    return float(wa_from_op_ratio(jnp.asarray(s / (s + op_eff))))


def _check_invariants(geom, state):
    """Trim-aware conservation checks: a pure-write drive holds every
    logical page mapped; an op-stream drive holds exactly ``mapped_pages``
    of them (the carried counter, cross-checked here from scratch)."""
    live = np.asarray(state["live"])
    valid = np.asarray(state["valid"])
    fill = np.asarray(state["fill"])
    pm = np.asarray(state["page_map"])
    blk_state = np.asarray(state["state"])
    degraded = int(state["drive_status"]) != 0
    mapped = pm >= 0
    n_mapped = int(mapped.sum())
    if degraded:
        # the op that killed the drive may lose its write (the retirement
        # emptied the pool mid-step); every later op froze, so ≤ 1
        assert int(state["n_dropped"]) <= 1, "degraded drive dropped >1"
    else:
        assert int(state["n_dropped"]) == 0, (
            "writes were dropped (pool exhausted)"
        )
    assert int(state["mapped_pages"]) == n_mapped, "carried mapped_pages"
    if int(state["n_trim"]) == 0 and int(state["n_dropped"]) == 0:
        assert n_mapped == geom.lba_pages, "pure-write drive fully mapped"
    # block-state machine: only the four legal states; RETIRED blocks are
    # terminal — carried counters (retired_blocks / grp_retired /
    # spares_left) conserve against full reductions
    assert set(np.unique(blk_state)) <= {0, 1, 2, 3}, "illegal block state"
    retired = blk_state == 3
    assert int(state["retired_blocks"]) == int(retired.sum()), (
        "carried retired_blocks"
    )
    group_of = np.asarray(state["group_of"])
    assert (group_of[retired] >= 0).all(), "retired block lost its group"
    grp_retired = np.asarray(state["grp_retired"], np.int64)
    np.testing.assert_array_equal(
        np.bincount(
            group_of[retired], minlength=grp_retired.shape[0]
        ).astype(np.int64),
        grp_retired,
        err_msg="carried grp_retired",
    )
    assert int(state["spares_left"]) >= 0, "spare pool over-drawn"
    assert (live[retired] == 0).all(), "retired block holds live pages"
    assert degraded == (int(state["degraded_at"]) >= 0), (
        "degraded_at inconsistent with drive_status"
    )
    assert live.sum() == n_mapped, "live-page conservation"
    assert valid.sum() == n_mapped, "valid-bitmap conservation"
    np.testing.assert_array_equal(valid.sum(1), live, err_msg="live==Σvalid")
    assert (fill >= live).all(), "fill ≥ live"
    # the packed mapping is a bijection onto valid slots
    mb = pm[mapped] // geom.pages_per_block
    ms = pm[mapped] % geom.pages_per_block
    assert valid[mb, ms].all(), "every mapped slot is valid"
    sl = np.asarray(state["slot_lba"])
    back = sl[mb, ms]
    np.testing.assert_array_equal(back, np.arange(geom.lba_pages)[mapped])
    # wear accounting: per-block P-E counts conserve against the carried
    # aggregates (erase_total / erase_sq_total) and the n_erase counter
    ec = np.asarray(state["erase_count"], np.int64)
    assert (ec >= 0).all(), "erase_count non-negative"
    assert ec.sum() == int(state["n_erase"]), "Σ erase_count == n_erase"
    assert int(state["erase_total"]) == ec.sum(), "carried erase_total"
    assert int(state["erase_sq_total"]) == int((ec * ec).sum()), (
        "carried erase_sq_total"
    )
    td = np.asarray(state["trim_dead"])
    assert (td >= 0).all(), "trim_dead non-negative"
    assert (td <= fill - live).all(), "trim_dead ≤ dead slots"
    if int(state["n_trim"]) == 0:
        assert (td == 0).all(), "pure-write drive has no trimmed slots"


class TestEquilibrium:
    """Paper Fig. 1: eq. 3 vs simulation under a uniform workload."""

    @pytest.mark.parametrize("r", [0.7, 0.8])
    def test_lru_matches_eq3(self, r):
        geom = dataclasses.replace(GEOM, lba_pba=r)
        mcfg = dataclasses.replace(M.single_group(), gc_policy="lru")
        res = M.simulate(geom, mcfg, [W.uniform(geom.lba_pages, 120_000)], seed=1)
        wa = res.wa_curve(10_000)[-4:].mean()
        assert wa == pytest.approx(_expected_wa(geom), rel=0.06)
        _check_invariants(geom, res.state)

    def test_greedy_at_least_as_good_as_lru(self):
        res_lru = M.simulate(
            GEOM, dataclasses.replace(M.single_group(), gc_policy="lru"),
            [W.uniform(GEOM.lba_pages, 120_000)], seed=1,
        )
        res_greedy = M.simulate(
            GEOM, M.single_group(), [W.uniform(GEOM.lba_pages, 120_000)], seed=1
        )
        assert res_greedy.wa_total <= res_lru.wa_total * 1.01

    def test_wa_increases_with_utilization(self):
        was = []
        for r in (0.65, 0.75, 0.85):
            geom = dataclasses.replace(GEOM, lba_pba=r)
            res = M.simulate(
                geom, M.single_group(), [W.uniform(geom.lba_pages, 100_000)], seed=2
            )
            was.append(res.wa_curve(10_000)[-3:].mean())
        assert was[0] < was[1] < was[2]


class TestSeparation:
    """Separating hot/cold pages reduces WA (paper §5 premise, Fig. 10 grey)."""

    def test_wolf_beats_single_group_on_skewed(self):
        phase = W.two_modal(GEOM.lba_pages, 150_000, p_hot=0.9, frac_hot=0.2)
        res_wolf = M.simulate(GEOM, M.wolf(), [phase], seed=3)
        res_single = M.simulate(GEOM, M.single_group(), [phase], seed=3)
        wa_w = res_wolf.wa_curve(10_000)[-4:].mean()
        wa_s = res_single.wa_curve(10_000)[-4:].mean()
        assert wa_w < wa_s * 0.90, f"wolf {wa_w:.3f} vs single {wa_s:.3f}"
        _check_invariants(GEOM, res_wolf.state)


class TestFrequencySwap:
    """Paper §6.1 (Figs. 6–7): Wolf adapts ~instantly; FDP pays ~1.5×PBA."""

    def test_wolf_vs_fdp_extra_migrations(self):
        n = 120_000
        ph1, ph2 = W.swap_phases(GEOM.lba_pages, n, p=(0.1, 0.9))
        results = {}
        for name, mcfg in (("wolf", M.wolf()), ("fdp", M.fdp())):
            swap = M.simulate(GEOM, mcfg, [ph1, ph2], seed=4)
            noswap = M.simulate(GEOM, mcfg, [ph1, ph1], seed=4)
            results[name] = (
                float(swap.mig[-1] - noswap.mig[-1]) / GEOM.pba_pages
            )
            _check_invariants(GEOM, swap.state)
        # paper: 0.7% vs 152.1%; reduced geometry reproduces the gap
        assert results["wolf"] < 0.15, results
        assert results["fdp"] > 0.5, results
        assert results["fdp"] / max(results["wolf"], 1e-3) > 5.0

    def test_wolf_total_wa_beats_fdp_across_swap(self):
        n = 100_000
        ph1, ph2 = W.swap_phases(GEOM.lba_pages, n, p=(0.1, 0.9))
        wa = {
            name: M.simulate(GEOM, mcfg, [ph1, ph2], seed=5).wa_total
            for name, mcfg in (("wolf", M.wolf()), ("fdp", M.fdp()))
        }
        assert wa["wolf"] < wa["fdp"]

    def test_pairwise_swap_matrix_sample(self):
        """Fig. 8 (sampled): swap the extreme pair of 5 exponential groups."""
        base = W.exponential_groups(GEOM.lba_pages, 80_000)
        swapped = W.pairwise_swap(base, 0, 4, 80_000)
        extra = {}
        for name, mcfg in (("wolf", M.wolf()), ("fdp", M.fdp())):
            s = M.simulate(GEOM, mcfg, [base, swapped], seed=6)
            b = M.simulate(GEOM, mcfg, [base, base], seed=6)
            extra[name] = float(s.mig[-1] - b.mig[-1]) / GEOM.pba_pages
        assert extra["wolf"] < extra["fdp"], extra


class TestGreedyVsLru:
    """Paper Fig. 2: after movement-op bursts, LRU's heuristic fails."""

    def test_greedy_no_worse_after_double_swap(self):
        n = 60_000
        ph1, ph2 = W.swap_phases(GEOM.lba_pages, n, p=(0.02, 0.98))
        phases = [ph1, ph2, dataclasses.replace(ph1, n_writes=n)]
        mig = {}
        for name, mcfg in (("greedy", M.wolf()), ("lru", M.wolf_lru())):
            res = M.simulate(GEOM, mcfg, phases, seed=7)
            # migrations in the final phase only
            third = len(res.mig) // 3
            mig[name] = float(res.mig[-1] - res.mig[2 * third])
        assert mig["greedy"] <= mig["lru"] * 1.05, mig


class TestDynamicWolf:
    """§5.2/§5.6: dynamic group creation/merging with the bloom detector."""

    def test_tpcc_like_runs_and_beats_single(self):
        phase = W.tpcc_like(GEOM.lba_pages, 150_000)
        res = M.simulate(GEOM, M.wolf_dynamic(), [phase], seed=8)
        _check_invariants(GEOM, res.state)
        n_groups = int(np.asarray(res.state["grp_active"]).sum())
        assert n_groups >= 2
        res_single = M.simulate(GEOM, M.single_group(), [phase], seed=8)
        assert res.wa_curve(10_000)[-4:].mean() < res_single.wa_curve(10_000)[-4:].mean()


class TestInvariantsProperty:
    @settings(max_examples=8, deadline=None)
    @given(
        st.sampled_from([(4, 32, 8), (8, 32, 16), (4, 64, 8)]),
        st.floats(min_value=0.6, max_value=0.85),
        st.integers(min_value=0, max_value=100),
        st.sampled_from(["wolf", "fdp", "single", "wolf_lru"]),
    )
    def test_state_invariants_random(self, geo, r, seed, manager):
        luns, bpl, ppb = geo
        geom = Geometry(
            n_luns=luns, blocks_per_lun=bpl, pages_per_block=ppb, lba_pba=r
        )
        mcfg = getattr(M, manager)() if manager != "single" else M.single_group()
        rng = np.random.default_rng(seed)
        frac = float(rng.uniform(0.2, 0.8))
        p_hot = float(rng.uniform(0.6, 0.95))
        phase = W.two_modal(geom.lba_pages, 25_000, p_hot=p_hot, frac_hot=frac)
        res = M.simulate(geom, mcfg, [phase], seed=seed)
        _check_invariants(geom, res.state)
        assert res.wa_total >= 1.0

    @pytest.mark.trim
    @settings(max_examples=8, deadline=None)
    @given(
        st.sampled_from([(4, 32, 8), (8, 32, 16)]),
        st.floats(min_value=0.6, max_value=0.85),
        st.integers(min_value=0, max_value=100),
        st.sampled_from(["wolf", "fdp", "single", "wolf_lru"]),
        st.sampled_from(["bulk", "reference"]),
    )
    def test_state_invariants_random_with_trims(
        self, geo, r, seed, manager, gc_impl
    ):
        """Random interleaved TRIMs (op-stream engine) under BOTH gc_impl
        paths: the full-reduction checker AND the carried
        mapped_pages/grp_live counters (ssd.assert_invariants) must hold."""
        from repro.core.ssd import assert_invariants

        luns, bpl, ppb = geo
        geom = Geometry(
            n_luns=luns, blocks_per_lun=bpl, pages_per_block=ppb, lba_pba=r
        )
        mcfg = getattr(M, manager)() if manager != "single" else M.single_group()
        rng = np.random.default_rng(seed)
        frac = float(rng.uniform(0.2, 0.8))
        p_hot = float(rng.uniform(0.6, 0.95))
        trim = float(rng.uniform(0.05, 0.5))
        phase = W.trimmed(
            W.two_modal(geom.lba_pages, 20_000, p_hot=p_hot, frac_hot=frac),
            trim,
        )
        res = M.simulate(geom, mcfg, [phase], seed=seed, gc_impl=gc_impl)
        label = f"{manager}/{gc_impl}/t={trim:.2f}"
        _check_invariants(geom, res.state)
        assert_invariants(res.state, label)
        assert int(res.state["n_trim"]) > 0, label
        assert res.wa_total >= 1.0
