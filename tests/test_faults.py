"""Fault-injection / bad-block retirement tests (ISSUE 8 acceptance).

Four pins on the fault layer:

- zero-rate traces are BIT-identical to the fault-free engine (faults are
  data, not step structure) — under plain jit AND under vmap with a mixed
  fleet sharing one compiled sub-batch;
- retirement conserves every carried counter (the numpy full-reduction
  checker in tests/test_simulator.py, extended with the RETIRED state);
- a drive that exhausts its spares degrades into an inert lane without
  perturbing its fleet-mates, and FleetResult's survival analytics see it;
- forced retirements shrink the OP the §5.5 model divides: measured WA on
  an LRU single-group drive tracks ``wa_from_op_ratio`` of the shrunken
  ratio (``analytics.wa_with_retirement``) within 15%.

The only field excluded from bit-identity comparisons is ``fault_draws``:
the per-erase draw counter advances whenever the fault layer is traced,
even at zero rates — it is bookkeeping for the counter-based uniform
stream, not drive state.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import analytics as A
from repro.core import managers as M
from repro.core import workloads as W
from repro.core.analytics import wa_from_op_ratio
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.simulator import SimContext, run
from repro.core.ssd import RETIRED, STATUS_DEGRADED, STATUS_OK, Geometry
from test_simulator import _check_invariants

pytestmark = pytest.mark.fault

GEOM = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8, lba_pba=0.7)
GEOM_BIG = Geometry(n_luns=8, blocks_per_lun=64, pages_per_block=16,
                    lba_pba=0.7)

# fault_draws advances per erase whenever the layer is traced, even with
# zero fault events — every bit-identity assertion excludes it
_DRAW_COUNTER = ("fault_draws",)


def _assert_states_equal(got, ref, label, *, skip=_DRAW_COUNTER):
    for key, ref_arr in ref.items():
        if key in skip:
            continue
        np.testing.assert_array_equal(
            np.asarray(got[key]), np.asarray(ref_arr),
            err_msg=f"{label}: state[{key}] diverged",
        )


class TestZeroRateBitIdentity:
    """Tracing the fault layer with an empty event set must not perturb a
    single bit of drive state: faults ride in the policy pytree, not in
    the step structure."""

    def test_jit_zero_rate_identical(self):
        phase = W.two_modal(GEOM.lba_pages, 12_000)
        ref = M.simulate(GEOM, M.wolf(), [phase], seed=1)
        res = M.simulate(GEOM, M.wolf(), [phase], seed=1, faults=True)
        np.testing.assert_array_equal(res.app, ref.app)
        np.testing.assert_array_equal(res.mig, ref.mig)
        _assert_states_equal(res.state, ref.state, "jit zero-rate")
        # the layer was actually traced: the draw counter advanced once
        # per erase while nothing fired and nobody halted
        assert int(res.state["fault_draws"]) == int(res.state["n_erase"])
        assert int(res.state["n_erase_fail"]) == 0
        assert int(res.state["n_halted"]) == 0
        assert int(res.state["retired_blocks"]) == 0

    def test_vmap_mixed_subbatch_identical(self):
        """A drive with an unreachable endurance limit forces the fault
        trace onto its whole sub-batch; every drive sharing the compiled
        step must stay bit-identical to its faultless solo run."""
        lba, n = GEOM.lba_pages, 10_000
        specs = [
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1,
                      name="plain"),
            DriveSpec(M.wolf(endurance_pe_limit=1_000_000),
                      (W.two_modal(lba, n),), seed=2, name="armed"),
        ]
        assert specs[1].mcfg.has_faults and not specs[0].mcfg.has_faults
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        assert len(fleet.shards) == 1, "drives must share one sub-batch"
        for i, s in enumerate(specs):
            ref = M.simulate(GEOM, s.mcfg, list(s.phases), seed=s.seed)
            np.testing.assert_array_equal(fleet.app[i], ref.app)
            np.testing.assert_array_equal(fleet.mig[i], ref.mig)
            _assert_states_equal(fleet.state(i), ref.state, s.label)
        np.testing.assert_array_equal(
            fleet.drive_status(), [STATUS_OK, STATUS_OK]
        )
        np.testing.assert_array_equal(fleet.time_to_degraded(), [-1, -1])
        assert (fleet.retired_fraction() == 0.0).all()


class TestRetirementInvariants:
    def test_wearout_retires_then_dies_gracefully(self):
        """Deterministic wear-out (fault_rate_worn=1) on a reachable P-E
        limit: the workload cycles every block past the limit, so each GC
        erase eventually retires its victim, the free pool drains, and the
        drive degrades instead of deadlocking — with every carried counter
        conserved against the full reductions."""
        mcfg = M.wolf_endurance(endurance_pe_limit=2)
        res = M.simulate(
            GEOM_BIG, mcfg, [W.uniform(GEOM_BIG.lba_pages, 20_000)], seed=3
        )
        state = res.state
        _check_invariants(GEOM_BIG, state)
        n_ret = int(state["retired_blocks"])
        assert n_ret > 0, "no block ever crossed the endurance limit"
        # a failed erase is UNDONE from wear accounting: retired blocks
        # sit exactly at the limit; at worn rate 1.0 every failed event
        # exhausts its whole retry ladder, so fail events == retirements
        blk = np.asarray(state["state"])
        ec = np.asarray(state["erase_count"])
        np.testing.assert_array_equal(ec[blk == RETIRED], 2)
        assert int(state["n_erase_fail"]) == n_ret
        # with ample spares, death comes through the pool door: a retiring
        # GC nets zero free blocks, so the pool drains to empty and the
        # drive freezes (no silent write-drop deadlock)
        assert int(state["drive_status"]) == STATUS_DEGRADED
        assert int(state["free_blocks"]) == 0
        assert int(state["spares_left"]) > 0
        assert int(state["degraded_at"]) > 0
        assert int(state["n_halted"]) > 0
        assert float(
            A.retired_fraction(state["retired_blocks"], GEOM_BIG.n_blocks)
        ) == pytest.approx(n_ret / GEOM_BIG.n_blocks)

    def test_probabilistic_faults_survive_on_spares(self):
        """An age-independent failure floor (fault_rate with a short retry
        ladder) retires the occasional block; the spare pool absorbs them
        and the drive stays healthy to the end of the stream."""
        mcfg = M.wolf(fault_rate=0.08, erase_max_retries=1)
        res = M.simulate(
            GEOM_BIG, mcfg, [W.uniform(GEOM_BIG.lba_pages, 20_000)], seed=3
        )
        state = res.state
        _check_invariants(GEOM_BIG, state)
        n_ret = int(state["retired_blocks"])
        assert n_ret > 0
        assert int(state["drive_status"]) == STATUS_OK
        assert int(state["n_halted"]) == 0
        assert int(state["spares_left"]) > 0
        # the retry ladder masks most failures: failed events strictly
        # outnumber retirements (retire prob is rate^(1+retries))
        assert int(state["n_erase_fail"]) > n_ret


class TestDegradedDrives:
    """Spare exhaustion / pool death freeze a drive into an inert lane —
    fleet-mates are untouched and the survival analytics see the death."""

    @pytest.fixture(scope="class")
    def fleet_and_specs(self):
        lba, n = GEOM.lba_pages, 12_000
        phase = W.two_modal(lba, n)
        specs = [
            DriveSpec(M.wolf(), (phase,), seed=1, name="healthy"),
            # pool death: limit=1 retires on every erase once the first
            # P-E cycle completes; a retiring GC nets zero free blocks
            DriveSpec(M.wolf_endurance(endurance_pe_limit=1),
                      (phase,), seed=2, name="pool-death"),
            # spare door: ample endurance events but only 5 spares
            DriveSpec(M.wolf_endurance(endurance_pe_limit=2,
                                       spare_blocks=5),
                      (phase,), seed=3, name="spare-death"),
        ]
        return simulate_fleet(GEOM, specs, sampler="numpy"), specs

    def test_fleet_runs_to_completion_and_reports(self, fleet_and_specs):
        fleet, specs = fleet_and_specs
        assert len(fleet.shards) == 1, "mixed fleet must share one shard"
        np.testing.assert_array_equal(
            fleet.drive_status(),
            [STATUS_OK, STATUS_DEGRADED, STATUS_DEGRADED],
        )
        ttd = fleet.time_to_degraded()
        assert ttd[0] == -1
        assert 0 < ttd[1] <= 12_000 and 0 < ttd[2] <= 12_000
        rfrac = fleet.retired_fraction()
        assert rfrac[0] == 0.0
        assert rfrac[1] > 0.0 and rfrac[2] > 0.0
        for i in range(len(specs)):
            _check_invariants(GEOM, fleet.state(i))

    def test_degraded_lane_is_frozen(self, fleet_and_specs):
        fleet, _ = fleet_and_specs
        for i in (1, 2):
            state = fleet.state(i)
            assert int(state["n_halted"]) > 0, "no op froze after death"
            # the trace is flat after death: no write lands, no migration
            t = int(fleet.time_to_degraded()[i])
            tail_a = fleet.app[i, t + 2:]
            tail_m = fleet.mig[i, t + 2:]
            assert tail_a.size > 0
            assert (tail_a == tail_a[0]).all(), "writes after death"
            assert (tail_m == tail_m[0]).all(), "migrations after death"

    def test_spare_door_drained_the_pool(self, fleet_and_specs):
        fleet, specs = fleet_and_specs
        state = fleet.state(2)
        assert int(state["spares_left"]) == 0
        assert int(state["retired_blocks"]) >= specs[2].mcfg.spare_blocks

    def test_survivor_unchanged_vs_alone(self, fleet_and_specs):
        fleet, specs = fleet_and_specs
        ref = M.simulate(
            GEOM, specs[0].mcfg, list(specs[0].phases), seed=specs[0].seed
        )
        np.testing.assert_array_equal(fleet.app[0], ref.app)
        np.testing.assert_array_equal(fleet.mig[0], ref.mig)
        _assert_states_equal(fleet.state(0), ref.state, "survivor")

    def test_survival_analytics(self, fleet_and_specs):
        fleet, _ = fleet_and_specs
        ttd = fleet.time_to_degraded()
        surv = np.asarray(
            A.survival_fraction(ttd, jnp.asarray([0, 12_000]))
        )
        assert surv[0] == pytest.approx(1.0)
        assert surv[1] == pytest.approx(1.0 / 3.0)
        curves = fleet.wa_vs_lifetime(window=2000)
        assert curves.shape == (3, 6)
        assert np.isfinite(curves[0]).all(), "survivor curve has holes"
        # dead drives stop writing: their late windows are NaN
        for i in (1, 2):
            assert np.isnan(curves[i, -1]), "dead drive still writing"
            assert np.isfinite(curves[i, 0]), "burn-in window lost"


class TestShrunkenOPModel:
    """Acceptance: forced retirements shrink physical space, and measured
    WA on an LRU single-group drive tracks ``wa_from_op_ratio`` of the
    shrunken OP ratio within ~15% (the §5.5 model on degraded geometry)."""

    N_SEED = 16
    PE_SEED = 1000

    def test_wa_tracks_shrunken_op(self):
        geom = GEOM
        mcfg = dataclasses.replace(
            M.single_group(), gc_policy="lru",
            endurance_pe_limit=self.PE_SEED, fault_rate_worn=1.0,
        )
        phase = W.uniform(geom.lba_pages, 50_000)
        st0, n_groups, assumed_p, fdp_rate, page_rates, _ = M.build_drive(
            geom, mcfg, [phase]
        )
        # pre-age N_SEED blocks to the limit: their next erase is the
        # (PE_SEED+1)-th, which retires them deterministically — nothing
        # else comes close, so EXACTLY those blocks retire
        k = geom.n_blocks
        chosen = np.arange(0, k, k // self.N_SEED)[: self.N_SEED]
        ec = np.zeros(k, np.int32)
        ec[chosen] = self.PE_SEED
        st0 = st0.replace(
            erase_count=jnp.asarray(ec),
            erase_total=st0.erase_total + self.N_SEED * self.PE_SEED,
            erase_sq_total=st0.erase_sq_total
            + self.N_SEED * self.PE_SEED**2,
            n_erase=st0.n_erase + self.N_SEED * self.PE_SEED,
        )
        ctx = SimContext(
            geom, mcfg, n_groups, use_bloom=False,
            use_movement=mcfg.movement_ops,
            can_demote=mcfg.td_mode != "static",
            use_dynamic=mcfg.dynamic_groups,
            use_closed_alloc=mcfg.alloc_mode
            in ("wolf", "optimal", "fdp_assumed"),
            with_faults=True,
        )
        rng = np.random.default_rng(11)
        st, trace = run(
            ctx, st0, phase.sample(rng),
            page_rate=page_rates[0], assumed_p=assumed_p, fdp_rate=fdp_rate,
        )
        res = M.RunResult(
            np.asarray(trace["app"]), np.asarray(trace["mig"]), st
        )
        _check_invariants(geom, res.state)
        assert int(st["retired_blocks"]) == self.N_SEED
        assert int(st["drive_status"]) == STATUS_OK
        blk = np.asarray(st["state"])
        np.testing.assert_array_equal(np.where(blk == RETIRED)[0], chosen)
        # §5.5 on the shrunken drive: OP loses the retired blocks' pages
        s = geom.lba_pages
        op_eff = (
            geom.pba_pages - 3 * geom.pages_per_block
            - self.N_SEED * geom.pages_per_block - s
        )
        expected = float(wa_from_op_ratio(jnp.asarray(s / (s + op_eff))))
        wa = res.wa_curve(10_000)[-3:].mean()
        assert wa == pytest.approx(expected, rel=0.15)
        # and WITHOUT the retirement term the model visibly underpredicts
        healthy = float(
            wa_from_op_ratio(jnp.asarray(s / (s + op_eff
                                              + self.N_SEED
                                              * geom.pages_per_block)))
        )
        assert wa > healthy * 1.05

    def test_wa_with_retirement_composes(self):
        """analytics.wa_with_retirement is exactly wa_from_op_ratio on the
        degraded ratio; at zero retired fraction it is the healthy model."""
        r = 0.7
        f = 0.125
        deg = A.degraded_op_ratio(r, f)
        assert float(deg) == pytest.approx(r / (1 - f))
        assert float(A.wa_with_retirement(r, f)) == pytest.approx(
            float(wa_from_op_ratio(jnp.asarray(deg))), rel=1e-6
        )
        assert float(A.wa_with_retirement(r, 0.0)) == pytest.approx(
            float(wa_from_op_ratio(jnp.asarray(r))), rel=1e-6
        )
        # saturates below 1 instead of diverging
        assert float(A.degraded_op_ratio(0.9, 0.5)) < 1.0


class TestFaultInvariantsProperty:
    @settings(max_examples=6, deadline=None)
    @given(
        st.sampled_from([(4, 32, 8), (8, 32, 16)]),
        st.integers(min_value=0, max_value=100),
        st.sampled_from(["wolf", "single"]),
        st.sampled_from([0.0, 0.02, 0.1]),
        st.sampled_from([0, 2]),
        st.sampled_from([0, 1, 3]),
    )
    def test_random_fault_streams_hold_invariants(
        self, geo, seed, manager, rate, limit, retries
    ):
        """Random op segments with random fault injection — age-independent
        rates, reachable endurance limits, shallow retry ladders — keep
        every carried counter consistent with the full reductions, whether
        the drive survives, degrades, or dies mid-stream."""
        luns, bpl, ppb = geo
        geom = Geometry(
            n_luns=luns, blocks_per_lun=bpl, pages_per_block=ppb,
            lba_pba=0.7,
        )
        base = M.wolf if manager == "wolf" else M.single_group
        mcfg = base(
            fault_rate=rate, endurance_pe_limit=limit,
            erase_max_retries=retries, fault_seed=seed,
        )
        rng = np.random.default_rng(seed)
        frac = float(rng.uniform(0.2, 0.8))
        phase = W.two_modal(geom.lba_pages, 15_000, frac_hot=frac)
        res = M.simulate(geom, mcfg, [phase], seed=seed, faults=True)
        state = res.state
        _check_invariants(geom, state)
        assert res.wa_total >= 1.0
        if rate == 0.0 and limit == 0:
            assert int(state["retired_blocks"]) == 0
            assert int(state["n_erase_fail"]) == 0
        if int(state["drive_status"]) == STATUS_DEGRADED:
            assert int(state["n_halted"]) > 0
