"""Pallas-vs-XLA attention routing: both paths must produce the same model
outputs (the flash kernel runs in interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.models import attention
from repro.models.registry import get_config, get_model, smoke_config

# whole-model Pallas-vs-XLA comparisons (interpret mode) are multi-minute in
# aggregate: tier-1, but out of the fast lane (scripts/run_tests.sh --fast)
pytestmark = pytest.mark.slow


@pytest.fixture(autouse=True)
def _restore_impl():
    yield
    attention.set_attention_impl("auto")


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x22b"])
def test_model_forward_same_under_pallas_attention(arch):
    cfg = smoke_config(get_config(arch))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = api.make_train_batch(
        ShapeConfig("s", seq_len=64, global_batch=2, kind="train"),
        jax.random.PRNGKey(1),
    )
    attention.set_attention_impl("xla")
    loss_xla = float(api.loss_fn(params, batch))
    attention.set_attention_impl("pallas")
    loss_pallas = float(api.loss_fn(params, batch))
    assert loss_pallas == pytest.approx(loss_xla, rel=1e-4), (loss_xla, loss_pallas)


def test_auto_stays_xla_on_cpu():
    assert attention._use_pallas(0) is False  # this container is CPU
