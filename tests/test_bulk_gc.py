"""Bulk-GC equivalence suite (the tentpole's acceptance bar).

The vectorized drain (``simulator._gc_drain_bulk``) must be
elementwise-identical to the seed per-page path (retained as
``simulator._gc_drain_reference``) — final state, ``n_erase``, ``n_mig``,
and WA curves — across allocation / GC / detector policy combinations,
under both jit (``managers.simulate``) and vmap (``simulate_fleet``).
"""

import inspect

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import managers as M
from repro.core import simulator as S
from repro.core import workloads as W
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.ssd import Geometry

GEOM = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8, lba_pba=0.7)
N_WRITES = 6_000

_MANAGERS = {
    "wolf": M.wolf,            # closed-form alloc, greedy GC, static TD
    "wolf_lru": M.wolf_lru,    # LRU GC under movement ops
    "fdp": M.fdp,              # assumed alloc, LRU GC, fdp demotion
    "wolf_dynamic": M.wolf_dynamic,  # bloom detector + dynamic groups
    "single": M.single_group,  # one group, size alloc
}


def _phases(workload: str, rng: np.random.Generator):
    lba = GEOM.lba_pages
    if workload == "two_modal":
        return [W.two_modal(
            lba, N_WRITES,
            p_hot=float(rng.uniform(0.6, 0.95)),
            frac_hot=float(rng.uniform(0.2, 0.8)),
        )]
    if workload == "tpcc":
        return [W.tpcc_like(lba, N_WRITES)]
    return list(W.swap_phases(lba, N_WRITES // 2))


def _assert_identical(a, b, label: str):
    np.testing.assert_array_equal(a.app, b.app, err_msg=f"{label}: app")
    np.testing.assert_array_equal(a.mig, b.mig, err_msg=f"{label}: mig")
    assert int(a.state["n_erase"]) == int(b.state["n_erase"]), label
    assert int(a.state["n_mig"]) == int(b.state["n_mig"]), label
    assert int(a.state["n_dropped"]) == 0, f"{label}: writes dropped"
    for key, arr in a.state.items():
        np.testing.assert_array_equal(
            np.asarray(arr), np.asarray(b.state[key]),
            err_msg=f"{label}: state[{key}]",
        )
    np.testing.assert_array_equal(
        a.wa_curve(1000), b.wa_curve(1000), err_msg=f"{label}: wa_curve"
    )


class TestBulkGcEquivalence:
    @settings(max_examples=6, deadline=None)
    @given(
        st.sampled_from(sorted(_MANAGERS)),
        st.sampled_from(["two_modal", "tpcc", "swap"]),
        st.integers(min_value=0, max_value=10_000),
    )
    def test_bulk_matches_reference_under_jit(self, manager, workload, seed):
        mcfg = _MANAGERS[manager]()
        phases = _phases(workload, np.random.default_rng(seed))
        bulk = M.simulate(GEOM, mcfg, phases, seed=seed, gc_impl="bulk")
        ref = M.simulate(GEOM, mcfg, phases, seed=seed, gc_impl="reference")
        _assert_identical(bulk, ref, f"{manager}/{workload}#{seed}")

    def test_bulk_matches_reference_under_vmap(self):
        """Whole mixed fleet (bloom + non-bloom partitions, a §5.1 sweep
        drive, multi-phase swap) under both drain implementations."""
        lba, n = GEOM.lba_pages, N_WRITES
        specs = [
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1),
            DriveSpec(M.fdp(), (W.two_modal(lba, n),), seed=2),
            DriveSpec(M.wolf_lru(), (W.tpcc_like(lba, n),), seed=3),
            DriveSpec(M.wolf(ewma_a=0.6, interval_frac=0.05),
                      (W.two_modal(lba, n),), seed=4),
            DriveSpec(M.wolf(), tuple(W.swap_phases(lba, n // 2)), seed=5),
            DriveSpec(M.wolf_dynamic(), (W.tpcc_like(lba, n),), seed=6),
        ]
        bulk = simulate_fleet(GEOM, specs, sampler="numpy", gc_impl="bulk")
        ref = simulate_fleet(
            GEOM, specs, sampler="numpy", gc_impl="reference"
        )
        np.testing.assert_array_equal(bulk.app, ref.app)
        np.testing.assert_array_equal(bulk.mig, ref.mig)
        for i, s in enumerate(specs):
            for key, arr in bulk.state(i).items():
                np.testing.assert_array_equal(
                    np.asarray(arr), np.asarray(ref.state(i)[key]),
                    err_msg=f"{s.label}: state[{key}]",
                )
        np.testing.assert_array_equal(
            bulk.wa_curves(1000), ref.wa_curves(1000)
        )


@pytest.mark.trim
class TestBulkGcTrimEquivalence:
    """Random interleaved TRIMs: the bulk drain must stay elementwise-
    identical to the reference oracle AND the carried counters
    (mapped_pages / grp_live, SimState.check_invariants) must hold under
    BOTH gc_impl paths — GC migrates pages around holes the trims punch."""

    @settings(max_examples=6, deadline=None)
    @given(
        st.sampled_from(["wolf", "wolf_lru", "fdp", "wolf_dynamic", "single"]),
        st.sampled_from(["two_modal", "tpcc"]),
        st.integers(min_value=0, max_value=10_000),
        st.sampled_from([0.1, 0.3, 0.5]),
    )
    def test_bulk_matches_reference_with_trims(
        self, manager, workload, seed, trim_frac
    ):
        from repro.core.ssd import assert_invariants

        mcfg = _MANAGERS[manager]()
        phases = [
            W.trimmed(ph, trim_frac)
            for ph in _phases(workload, np.random.default_rng(seed))
        ]
        bulk = M.simulate(GEOM, mcfg, phases, seed=seed, gc_impl="bulk")
        ref = M.simulate(GEOM, mcfg, phases, seed=seed, gc_impl="reference")
        label = f"{manager}/{workload}#{seed}/t={trim_frac}"
        _assert_identical(bulk, ref, label)
        assert_invariants(bulk.state, label)
        assert int(bulk.state["n_trim"]) > 0

    def test_bulk_matches_reference_with_trims_under_vmap(self):
        """A mixed op-stream fleet (trim + pure-write drives across
        partitions) under both drain implementations."""
        lba, n = GEOM.lba_pages, N_WRITES
        specs = [
            DriveSpec(M.wolf(), (W.trimmed(W.two_modal(lba, n), 0.25),),
                      seed=1),
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=2),
            DriveSpec(M.fdp(), (W.trimmed(W.two_modal(lba, n), 0.4),),
                      seed=3),
            DriveSpec(M.wolf_dynamic(), (W.tpcc_churn(lba, n),), seed=4),
            DriveSpec(M.single_group(), (W.tpcc_churn(lba, n),), seed=5),
        ]
        bulk = simulate_fleet(GEOM, specs, sampler="numpy", gc_impl="bulk")
        ref = simulate_fleet(GEOM, specs, sampler="numpy",
                             gc_impl="reference")
        np.testing.assert_array_equal(bulk.app, ref.app)
        np.testing.assert_array_equal(bulk.mig, ref.mig)
        for i, s in enumerate(specs):
            for key, arr in bulk.state(i).items():
                np.testing.assert_array_equal(
                    np.asarray(arr), np.asarray(ref.state(i)[key]),
                    err_msg=f"{s.label}: state[{key}]",
                )


class TestBulkGcStructure:
    def test_no_fori_loop_over_victim_slots(self):
        """Acceptance bar: the default GC path contains no fori_loop; only
        the retained reference oracle may."""
        assert "fori_loop" not in inspect.getsource(S._gc_drain_bulk)
        assert "fori_loop" not in inspect.getsource(S._gc_one)
        assert "fori_loop" in inspect.getsource(S._gc_drain_reference)

    def test_default_context_uses_bulk(self):
        ctx = S.SimContext(GEOM, M.wolf(), 2)
        assert ctx.gc_impl == "bulk"

    def test_unknown_gc_impl_rejected(self):
        ctx = S.SimContext(GEOM, M.wolf(), 2, gc_impl="nope")
        with pytest.raises(AssertionError):
            S._gc_one(  # asserts on gc_impl before touching any state
                ctx, None, 0, {}, lambda s, l: 0.0, False
            )
