"""End-to-end behaviour tests for the paper's system.

These exercise the public entry points the way a user would: the SSD
experiment campaign, the training launcher, and the serving launcher —
in-process, at smoke scale.
"""

import numpy as np
import pytest


class TestSsdCampaign:
    def test_wolf_dominates_across_workloads(self):
        """The paper's bottom line, end to end: under both a stable skewed
        workload and a swap workload, Wolf's total WA ≤ FDP's."""
        from repro.core import managers as M
        from repro.core import workloads as W
        from repro.core.ssd import Geometry

        geom = Geometry(n_luns=4, blocks_per_lun=48, pages_per_block=16)
        lba = geom.lba_pages
        scenarios = {
            "stable": [W.two_modal(lba, 50_000)],
            "swap": list(W.swap_phases(lba, 40_000)),
        }
        for name, phases in scenarios.items():
            wa = {
                mgr: M.simulate(geom, preset(), phases, seed=0).wa_total
                for mgr, preset in (("wolf", M.wolf), ("fdp", M.fdp))
            }
            assert wa["wolf"] <= wa["fdp"] * 1.02, (name, wa)

    def test_model_predicts_simulator_across_geometry(self):
        """Eq. 3 is geometry-free: two different geometries at the same
        LBA/PBA land on the same WA (±10%)."""
        import dataclasses

        from repro.core import managers as M
        from repro.core import workloads as W
        from repro.core.ssd import Geometry

        was = []
        for bpl, ppb in ((48, 16), (24, 32)):
            geom = Geometry(n_luns=4, blocks_per_lun=bpl, pages_per_block=ppb)
            mcfg = dataclasses.replace(M.single_group(), gc_policy="lru")
            res = M.simulate(geom, mcfg, [W.uniform(geom.lba_pages, 80_000)], seed=1)
            was.append(float(res.wa_curve(8000)[-3:].mean()))
        assert was[0] == pytest.approx(was[1], rel=0.10), was


class TestTrainLauncher:
    def test_train_main_runs_and_learns(self, tmp_path):
        from repro.launch.train import main

        rc = main([
            "--arch", "internlm2-1.8b", "--smoke",
            "--steps", "8", "--batch", "4", "--seq", "32",
            "--checkpoint-dir", str(tmp_path), "--checkpoint-every", "4",
            "--log-every", "4",
        ])
        assert rc == 0
        from repro.train.checkpoint import latest_step

        assert latest_step(tmp_path) == 8


class TestServeLauncher:
    def test_serve_main_drains(self):
        from repro.launch.serve import main

        rc = main(["--requests", "3", "--max-new", "6", "--prompt-len", "8",
                   "--blocks", "96", "--page", "8"])
        assert rc == 0
