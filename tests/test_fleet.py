"""Fleet runner tests: the vmapped batch must agree elementwise with
per-drive ``managers.simulate`` loops, and the JAX-native on-device sampler
must match the NumPy ``Phase.sample`` distribution."""

import jax
import numpy as np
import pytest

from repro.core import managers as M
from repro.core import workloads as W
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.ssd import Geometry

GEOM = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8, lba_pba=0.7)
N_WRITES = 12_000


def _grid_specs(lba, n):
    return [
        DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1),
        DriveSpec(M.fdp(), (W.two_modal(lba, n),), seed=2),
        DriveSpec(M.single_group(), (W.uniform(lba, n),), seed=3),
        DriveSpec(M.wolf_lru(), (W.tpcc_like(lba, n),), seed=4),
        DriveSpec(M.wolf(), tuple(W.swap_phases(lba, n // 2)), seed=5),
        # bloom drive: exercises the bloom sub-batch (filter width must
        # match the standalone run — padding is per-partition)
        DriveSpec(M.wolf_dynamic(), (W.tpcc_like(lba, n),), seed=6),
    ]


class TestFleetEquivalence:
    @pytest.fixture(scope="class")
    def fleet_and_refs(self):
        specs = _grid_specs(GEOM.lba_pages, N_WRITES)
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        refs = [
            M.simulate(GEOM, s.mcfg, list(s.phases), seed=s.seed)
            for s in specs
        ]
        return specs, fleet, refs

    def test_traces_elementwise_identical(self, fleet_and_refs):
        specs, fleet, refs = fleet_and_refs
        for i, (s, ref) in enumerate(zip(specs, refs)):
            np.testing.assert_array_equal(
                fleet.app[i], ref.app, err_msg=f"app trace diverged: {s.label}"
            )
            np.testing.assert_array_equal(
                fleet.mig[i], ref.mig, err_msg=f"mig trace diverged: {s.label}"
            )

    def test_final_states_elementwise_identical(self, fleet_and_refs):
        specs, fleet, refs = fleet_and_refs
        for i, (s, ref) in enumerate(zip(specs, refs)):
            for key, ref_arr in ref.state.items():
                got = np.asarray(fleet.state(i)[key])
                ref_arr = np.asarray(ref_arr)
                if ref_arr.shape != got.shape:
                    # per-group arrays are padded from the drive's own cap
                    # to its sub-batch's g_max; the pad must stay inactive
                    g = s.mcfg.max_groups
                    assert got.shape[0] >= g, (s.label, key)
                    if key.startswith("bloom_") and key != "bloom_writes":
                        # filter bit-width scales with 1/max_groups — shapes
                        # are incomparable; a non-bloom drive leaves both
                        # untouched (all-False)
                        assert not got.any() and not ref_arr.any(), (
                            s.label, key,
                        )
                        continue
                    if key == "grp_active":
                        assert not got[g:].any(), (s.label, key)
                    got = got[:g]
                np.testing.assert_array_equal(
                    got, ref_arr, err_msg=f"{s.label}: state[{key}] diverged"
                )

    def test_wa_matches_per_drive(self, fleet_and_refs):
        specs, fleet, refs = fleet_and_refs
        for i, ref in enumerate(refs):
            assert fleet.wa_total[i] == pytest.approx(ref.wa_total, abs=0)
            np.testing.assert_array_equal(
                fleet.result(i).wa_curve(2000), ref.wa_curve(2000)
            )

    def test_mixed_group_caps_stack(self):
        """wolf_dynamic (12 group slots) and single (1) share one vmap."""
        lba, n = GEOM.lba_pages, 6_000
        specs = [
            DriveSpec(M.wolf_dynamic(), (W.tpcc_like(lba, n),), seed=0),
            DriveSpec(M.single_group(), (W.two_modal(lba, n),), seed=0),
        ]
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        for i, s in enumerate(specs):
            ref = M.simulate(GEOM, s.mcfg, list(s.phases), seed=s.seed)
            np.testing.assert_array_equal(fleet.app[i], ref.app)
            np.testing.assert_array_equal(fleet.mig[i], ref.mig)
        # the single-group drive must actually behave single-group
        grp_active = np.asarray(fleet.state(1)["grp_active"])
        assert grp_active.sum() == 1

    def test_jax_sampler_runs_and_preserves_invariants(self):
        specs = _grid_specs(GEOM.lba_pages, 6_000)
        fleet = simulate_fleet(GEOM, specs, sampler="jax")
        assert np.all(fleet.wa_total >= 1.0)
        for i in range(len(specs)):
            state = fleet.state(i)
            assert int(state["n_dropped"]) == 0
            live = np.asarray(state["live"])
            assert live.sum() == GEOM.lba_pages, "live-page conservation"
            valid = np.asarray(state["valid"])
            np.testing.assert_array_equal(valid.sum(1), live)


@pytest.mark.wear
class TestWeightSweepEquivalence:
    """The (α, β, γ, τ) victim-score weights are traced per-drive data: a
    mixed-weight 6-drive fleet must agree elementwise with per-drive
    ``managers.simulate`` runs — greedy/LRU legacy points, the wear and
    trim-aware presets, and an explicit β override, all in one vmap."""

    def test_mixed_weight_fleet_matches_per_drive(self):
        import dataclasses

        lba, n = GEOM.lba_pages, 8_000
        phase = W.two_modal(lba, n, p_hot=0.9, frac_hot=0.2)
        specs = [
            DriveSpec(M.wolf(), (phase,), seed=1, name="greedy"),
            DriveSpec(M.wolf_lru(), (phase,), seed=1, name="lru"),
            DriveSpec(M.wolf_wear(), (phase,), seed=1, name="wear"),
            DriveSpec(M.wolf_wear(gc_beta=1.0), (phase,), seed=1,
                      name="wear-b1"),
            DriveSpec(M.wolf_trim_aware(), (phase,), seed=1, name="trim-aw"),
            DriveSpec(
                dataclasses.replace(
                    M.wolf(), gc_alpha=1.0, gc_beta=0.5, gc_gamma=0.25
                ),
                (phase,), seed=1, name="mixed",
            ),
        ]
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        for i, s in enumerate(specs):
            ref = M.simulate(GEOM, s.mcfg, list(s.phases), seed=s.seed)
            np.testing.assert_array_equal(
                fleet.app[i], ref.app, err_msg=f"app diverged: {s.label}"
            )
            np.testing.assert_array_equal(
                fleet.mig[i], ref.mig, err_msg=f"mig diverged: {s.label}"
            )
            for key, arr in ref.state.items():
                np.testing.assert_array_equal(
                    np.asarray(fleet.state(i)[key]), np.asarray(arr),
                    err_msg=f"{s.label}: state[{key}]",
                )
        # a pure-write stream leaves τ inert: trim-aware ≡ greedy exactly
        np.testing.assert_array_equal(fleet.app[4], fleet.app[0])
        np.testing.assert_array_equal(fleet.mig[4], fleet.mig[0])
        # the wear drives must actually diverge from greedy (β is live)
        assert not np.array_equal(fleet.mig[2], fleet.mig[0])
        # common random numbers: divergence between β points is the policy's
        assert not np.array_equal(fleet.mig[2], fleet.mig[3])


class TestPolicyConstantSweeps:
    """§5.1 constants (ewma_a, interval length) are per-drive policy data:
    one batch can sweep them, elementwise-identical to per-drive runs."""

    def test_ewma_and_interval_sweep_in_one_batch(self):
        lba, n = GEOM.lba_pages, 6_000
        specs = [
            DriveSpec(M.wolf(ewma_a=0.1), (W.two_modal(lba, n),), seed=0,
                      name="ewma=0.1"),
            DriveSpec(M.wolf(ewma_a=0.6), (W.two_modal(lba, n),), seed=0,
                      name="ewma=0.6"),
            DriveSpec(M.wolf(interval_frac=0.05), (W.two_modal(lba, n),),
                      seed=0, name="h=0.05·LBA"),
            DriveSpec(M.wolf(interval_frac=0.1), (W.two_modal(lba, n),),
                      seed=0, name="h=0.1·LBA"),
        ]
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        migs = {}
        for i, s in enumerate(specs):
            ref = M.simulate(GEOM, s.mcfg, list(s.phases), seed=s.seed)
            np.testing.assert_array_equal(
                fleet.app[i], ref.app, err_msg=f"app diverged: {s.label}"
            )
            np.testing.assert_array_equal(
                fleet.mig[i], ref.mig, err_msg=f"mig diverged: {s.label}"
            )
            migs[s.label] = int(fleet.mig[i][-1])
        # the sweep must actually exercise different dynamics: common random
        # numbers (same seed/phases), so any divergence is the policy's
        assert migs["ewma=0.1"] != migs["ewma=0.6"], migs
        assert migs["h=0.05·LBA"] != migs["h=0.1·LBA"], migs


class TestClosedFormAnalytics:
    """Satellite: per-drive eq. 3/5 predictions vs simulated equilibrium."""

    def test_predicted_wa_tracks_simulation(self):
        import dataclasses

        lba, n = GEOM.lba_pages, 40_000
        specs = [
            # eq. 3 models LRU victim decay (paper Fig. 1); greedy GC beats
            # it by construction, so the tight check uses an LRU drive
            DriveSpec(
                dataclasses.replace(M.single_group(), gc_policy="lru"),
                (W.uniform(lba, n),), seed=1, name="single-lru/uniform",
            ),
            DriveSpec(M.wolf(), (W.two_modal(lba, n),), seed=1,
                      name="wolf/two_modal"),
        ]
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        pred = fleet.predicted_wa()
        assert np.all(pred >= 1.0) and np.all(np.isfinite(pred))
        err = fleet.model_error(window=n // 10)
        # eq. 3 on a uniform single-group drive is the paper's Fig. 1 fit;
        # the multi-group eq. 5 sum stays a coarse but bounded model
        assert abs(err[0]) < 0.15, (pred, err)
        assert abs(err[1]) < 0.35, (pred, err)


class TestDeviceSampler:
    def _chi_square(self, counts, expected):
        counts = np.asarray(counts, np.float64)
        expected = np.asarray(expected, np.float64)
        keep = expected > 0
        return float(
            np.sum((counts[keep] - expected[keep]) ** 2 / expected[keep])
        )

    def test_group_distribution_matches_numpy_sample(self):
        """Per-group write counts: chi-square of the device stream against
        the phase probabilities stays within the same band as NumPy's."""
        lba, n = 20_000, 120_000
        phase = W.tpcc_like(lba, n)
        params = W.phase_param_arrays([phase])
        lbas_dev = np.asarray(
            W.sample_phases_device(jax.random.PRNGKey(0), params, n)
        )
        lbas_np = phase.sample(np.random.default_rng(0))
        edges = np.concatenate([[0], np.cumsum(phase.sizes)])
        expected = np.asarray(phase.probs) * n
        chi_dev = self._chi_square(
            np.histogram(lbas_dev, bins=edges)[0], expected
        )
        chi_np = self._chi_square(
            np.histogram(lbas_np, bins=edges)[0], expected
        )
        # 99.9th percentile of chi2(df=2) ≈ 13.8; both samplers must sit
        # inside it, i.e. device sampling is as faithful as host sampling
        assert chi_dev < 13.8, (chi_dev, chi_np)
        assert chi_np < 13.8, (chi_dev, chi_np)

    def test_within_group_uniformity(self):
        lba, n = 8_000, 200_000
        phase = W.two_modal(lba, n, p_hot=0.5, frac_hot=0.5)
        params = W.phase_param_arrays([phase])
        lbas = np.asarray(
            W.sample_phases_device(jax.random.PRNGKey(7), params, n)
        )
        assert lbas.min() >= 0 and lbas.max() < lba
        # chi-square over 16 sub-bins of the hot group vs uniform
        hot = lbas[lbas >= phase.sizes[0]] - phase.sizes[0]
        counts, _ = np.histogram(hot, bins=16, range=(0, phase.sizes[1]))
        chi = self._chi_square(counts, np.full(16, len(hot) / 16))
        assert chi < 37.7  # 99.9th percentile of chi2(df=15)

    def test_phase_boundaries_respected(self):
        lba = 6_000
        ph1, ph2 = W.swap_phases(lba, 5_000)
        params = W.phase_param_arrays([ph1, ph2])
        lbas = np.asarray(
            W.sample_phases_device(jax.random.PRNGKey(3), params, 10_000)
        )
        half = lba // 2
        # phase 1 writes 90% to the upper half, phase 2 mirrors it
        frac_hi_1 = (lbas[:5_000] >= half).mean()
        frac_hi_2 = (lbas[5_000:] >= half).mean()
        assert frac_hi_1 == pytest.approx(0.9, abs=0.02)
        assert frac_hi_2 == pytest.approx(0.1, abs=0.02)
