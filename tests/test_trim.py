"""TRIM subsystem acceptance suite (the tentpole's §6-style experiment).

Frankie et al. (arXiv:1208.1794): TRIMmed logical space is dynamic
over-provisioning — holding a fraction t of the LBA trimmed at steady
state moves the drive's operating point to the effective OP ratio
``r·(1-t)``, so equilibrium WA must track
``wa_from_op_ratio(effective_op_ratio(r, t))`` and fall monotonically in
t for every policy. Both are asserted here over one vmapped op-stream
fleet per test (the utilization × trim-rate sweep the ISSUE names),
plus engine-level sanity: steady-state mapped fraction ≈ 1 - t and the
carried ``mapped_pages``/``grp_live`` counters never drift.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import analytics as A
from repro.core import managers as M
from repro.core import workloads as W
from repro.core.fleet import DriveSpec, simulate_fleet
from repro.core.ssd import Geometry, assert_invariants

pytestmark = pytest.mark.trim

GEOM = Geometry(n_luns=4, blocks_per_lun=32, pages_per_block=8, lba_pba=0.75)
TRIM_FRACS = (0.0, 0.1, 0.25, 0.5)


def _equilibrium_wa(fleet, i, window):
    return float(np.mean(fleet.result(i).wa_curve(window)[-3:]))


class TestEffectiveOpSweep:
    """Acceptance bar: the LRU single-group utilization sweep lands within
    15% of the closed-form effective-OP model at every trim fraction."""

    def test_lru_single_group_tracks_model(self):
        n = 40_000
        mcfg = dataclasses.replace(M.single_group(), gc_policy="lru")
        specs = [
            DriveSpec(mcfg, (W.trimmed(W.uniform(GEOM.lba_pages, n), t),),
                      seed=3, name=f"lru/t={t}")
            for t in TRIM_FRACS
        ]
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        # reserve-adjusted base utilization, as in the Fig.-1 equilibrium
        # test: pool reserve + open blocks hold ~3 blocks of spare space
        usable = GEOM.pba_pages - 3 * GEOM.pages_per_block
        r_base = GEOM.lba_pages / usable
        window = 4_000
        for i, t in enumerate(TRIM_FRACS):
            assert_invariants(fleet.state(i), f"t={t}")
            assert int(fleet.state(i)["n_dropped"]) == 0
            # the stream holds ~t of the LBA trimmed at steady state
            t_meas = fleet.trim_fraction()[i]
            assert t_meas == pytest.approx(t, abs=0.03), (t, t_meas)
            wa_sim = _equilibrium_wa(fleet, i, window)
            wa_model = float(A.wa_from_op_ratio(
                A.effective_op_ratio(r_base, t_meas)
            ))
            assert wa_sim == pytest.approx(wa_model, rel=0.15), (
                f"t={t}: simulated {wa_sim:.3f} vs model {wa_model:.3f}"
            )

    def test_wa_with_trim_composition(self):
        """wa_with_trim is exactly the advertised composition."""
        r, t = 0.72, 0.25
        assert float(A.wa_with_trim(r, t)) == pytest.approx(
            float(A.wa_from_op_ratio(A.effective_op_ratio(r, t))), rel=1e-6
        )


class TestMonotoneInTrimFraction:
    """Acceptance bar: WA decreases monotonically in t for every policy
    cell. Same seed per policy → common random numbers, and the op draw
    (u_op < t) couples the trim sets monotonically across t, so the
    comparison is variance-free by construction."""

    @pytest.mark.parametrize("preset", ["wolf", "fdp", "single"])
    def test_wa_monotone_decreasing(self, preset):
        n = 20_000
        make = {"wolf": M.wolf, "fdp": M.fdp, "single": M.single_group}[preset]
        specs = [
            DriveSpec(
                make(), (W.trimmed(W.two_modal(GEOM.lba_pages, n), t),),
                seed=5, name=f"{preset}/t={t}",
            )
            for t in TRIM_FRACS
        ]
        fleet = simulate_fleet(GEOM, specs, sampler="numpy")
        window = 2_000
        was = [_equilibrium_wa(fleet, i, window) for i in range(len(specs))]
        for i, t in enumerate(TRIM_FRACS):
            assert_invariants(fleet.state(i), f"{preset}/t={t}")
        for a, b, t in zip(was, was[1:], TRIM_FRACS[1:]):
            assert b < a, (
                f"{preset}: WA {was} not decreasing at t={t}"
            )


class TestTpccChurn:
    """The insert/update/delete lifecycle workload: runs under every
    engine, holds its hot table partially trimmed, and frees WA relative
    to the trim-free tpcc_like shape."""

    def test_churn_trims_land_in_hot_group(self):
        n = 20_000
        res = M.simulate(GEOM, M.wolf(), [W.tpcc_churn(GEOM.lba_pages, n)],
                         seed=7)
        assert_invariants(res.state, "tpcc_churn")
        assert int(res.state["n_trim"]) > 0
        assert int(res.state["n_dropped"]) == 0
        # the churned (hot) group floats below full occupancy; the
        # append-only cold group stays fully mapped
        sizes = W.tpcc_like(GEOM.lba_pages, n).sizes
        grp_live = np.asarray(res.state["grp_live"])
        assert grp_live[0] == sizes[0], "cold group must stay fully mapped"
        assert grp_live[2] < sizes[2] * 0.85, "hot group must churn"

    def test_churn_wa_below_pure_write_tpcc(self):
        n = 20_000
        churn = M.simulate(GEOM, M.wolf(), [W.tpcc_churn(GEOM.lba_pages, n)],
                           seed=8)
        pure = M.simulate(GEOM, M.wolf(), [W.tpcc_like(GEOM.lba_pages, n)],
                          seed=8)
        assert churn.wa_total < pure.wa_total


class TestTrimEngineBasics:
    def test_retrim_and_remap_roundtrip(self):
        """A trim-heavy stream keeps the carried counters exact through
        unmap → re-map cycles (split and oracle engines agree)."""
        n = 8_000
        phases = [W.trimmed(W.uniform(GEOM.lba_pages, n), 0.5)]
        split = M.simulate(GEOM, M.single_group(), phases, seed=9)
        oracle = M.simulate(GEOM, M.single_group(), phases, seed=9,
                            fast_path=False, gc_impl="reference")
        for key, arr in split.state.items():
            np.testing.assert_array_equal(
                np.asarray(arr), np.asarray(oracle.state[key]),
                err_msg=f"state[{key}]",
            )
        st = split.state
        assert int(st["n_trim"]) > 0
        assert int(st["mapped_pages"]) == int(
            np.asarray(st["page_map"] >= 0).sum()
        )
        # writes + trims == events
        assert int(st["n_app"]) + int(st["n_trim"]) == n

    def test_device_sampler_matches_trim_distribution(self):
        """The on-device op sampler holds the same steady-state trimmed
        fraction as the host sampler."""
        n = 20_000
        t = 0.3
        spec = [DriveSpec(M.single_group(),
                          (W.trimmed(W.uniform(GEOM.lba_pages, n), t),),
                          seed=11)]
        for sampler in ("numpy", "jax"):
            fleet = simulate_fleet(GEOM, spec, sampler=sampler)
            assert fleet.trim_fraction()[0] == pytest.approx(t, abs=0.04), (
                sampler
            )
            assert_invariants(fleet.state(0), sampler)
