"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode on CPU).

Tolerances: fp32 tight; bf16 loose (scores are rounded to bf16 before
softmax — the same trade every production bf16 attention kernel makes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention.kernel import flash_attention

# interpret-mode shape/dtype sweeps take minutes in aggregate: keep them in
# tier-1 but out of the fast lane (scripts/run_tests.sh --fast)
pytestmark = pytest.mark.slow
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gc_compact.kernel import gc_compact
from repro.kernels.gc_compact.ref import gc_compact_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )


def _mk(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.5).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,sq,skv,hq,hkv,d,causal,window",
        [
            (1, 128, 128, 4, 4, 64, True, 0),    # MHA causal
            (2, 256, 256, 8, 2, 64, True, 0),    # GQA
            (2, 128, 128, 4, 1, 128, True, 0),   # MQA, d=128
            (1, 256, 256, 4, 2, 64, True, 64),   # sliding window
            (2, 64, 192, 2, 2, 64, False, 0),    # cross (Sq≠Skv, non-causal)
            (1, 100, 100, 4, 4, 64, True, 0),    # ragged tail (non-multiple)
        ],
    )
    def test_matches_ref(self, dtype, b, sq, skv, hq, hkv, d, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = _mk(ks[0], (b, sq, hq, d), dtype)
        k = _mk(ks[1], (b, skv, hkv, d), dtype)
        v = _mk(ks[2], (b, skv, hkv, d), dtype)
        out = flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=64, block_kv=64, interpret=True,
        )
        ref = flash_attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )

    @settings(max_examples=10, deadline=None)
    @given(
        st.sampled_from([64, 96, 128, 200]),
        st.sampled_from([(4, 4), (4, 2), (8, 1)]),
        st.sampled_from([64, 128]),
        st.integers(min_value=0, max_value=3),
    )
    def test_property_random_shapes(self, s, heads, d, seed):
        hq, hkv = heads
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = _mk(ks[0], (1, s, hq, d), jnp.float32)
        k = _mk(ks[1], (1, s, hkv, d), jnp.float32)
        v = _mk(ks[2], (1, s, hkv, d), jnp.float32)
        out = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5
        )


class TestPagedAttention:
    def _case(self, b, hq, hkv, d, n, p, m, dtype, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = _mk(ks[0], (b, hq, d), dtype)
        kp = _mk(ks[1], (n, p, hkv, d), dtype)
        vp = _mk(ks[2], (n, p, hkv, d), dtype)
        rng = np.random.default_rng(seed)
        lengths = rng.integers(1, m * p + 1, b).astype(np.int32)
        tables = np.full((b, m), -1, np.int32)
        for i in range(b):
            npages = -(-int(lengths[i]) // p)
            tables[i, :npages] = rng.choice(n, npages, replace=False)
        return q, kp, vp, jnp.asarray(tables), jnp.asarray(lengths)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,hq,hkv,d,n,p,m",
        [
            (2, 4, 4, 64, 16, 16, 4),   # MHA
            (4, 8, 2, 64, 32, 16, 6),   # GQA
            (2, 8, 1, 128, 16, 32, 3),  # MQA, d=128
            (1, 4, 2, 64, 8, 8, 8),     # long table
        ],
    )
    def test_matches_ref(self, dtype, b, hq, hkv, d, n, p, m):
        q, kp, vp, tables, lengths = self._case(b, hq, hkv, d, n, p, m, dtype)
        out = paged_attention(q, kp, vp, tables, lengths, interpret=True)
        ref = paged_attention_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )

    def test_single_token_sequence(self):
        q, kp, vp, tables, lengths = self._case(2, 4, 2, 64, 8, 16, 2, jnp.float32)
        lengths = jnp.asarray([1, 1], jnp.int32)
        out = paged_attention(q, kp, vp, tables, lengths, interpret=True)
        ref = paged_attention_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=1000))
    def test_property_random_tables(self, seed):
        q, kp, vp, tables, lengths = self._case(3, 4, 2, 64, 24, 8, 5, jnp.float32, seed)
        out = paged_attention(q, kp, vp, tables, lengths, interpret=True)
        ref = paged_attention_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


class TestGcCompact:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n,p,h,d,m", [(16, 8, 2, 64, 12), (8, 16, 1, 128, 5)])
    def test_matches_ref(self, dtype, n, p, h, d, m):
        rng = np.random.default_rng(1)
        kp = _mk(jax.random.PRNGKey(0), (n, p, h, d), dtype)
        vp = _mk(jax.random.PRNGKey(1), (n, p, h, d), dtype)
        # distinct destinations; a couple of no-op rows
        dst_flat = rng.choice(n * p, m, replace=False)
        src_flat = rng.choice(n * p, m, replace=False)
        sb, ss = (src_flat // p).astype(np.int32), (src_flat % p).astype(np.int32)
        db, ds = (dst_flat // p).astype(np.int32), (dst_flat % p).astype(np.int32)
        sb[1] = -1
        sb[m - 1] = -1
        args = tuple(map(jnp.asarray, (sb, ss, db, ds)))
        got_k, got_v = gc_compact(kp, vp, *args, interpret=True)
        ref_k, ref_v = gc_compact_ref(kp, vp, *args)
        np.testing.assert_array_equal(np.asarray(got_k), np.asarray(ref_k))
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(ref_v))

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=1000))
    def test_property_random_moves(self, seed):
        rng = np.random.default_rng(seed)
        n, p, h, d = 12, 8, 2, 64
        m = int(rng.integers(1, 20))
        kp = _mk(jax.random.PRNGKey(seed), (n, p, h, d), jnp.float32)
        vp = _mk(jax.random.PRNGKey(seed + 1), (n, p, h, d), jnp.float32)
        dst_flat = rng.choice(n * p, m, replace=False)
        src_flat = rng.choice(n * p, m, replace=False)
        sb = (src_flat // p).astype(np.int32)
        ss = (src_flat % p).astype(np.int32)
        db = (dst_flat // p).astype(np.int32)
        ds = (dst_flat % p).astype(np.int32)
        noop = rng.random(m) < 0.2
        sb = np.where(noop, -1, sb).astype(np.int32)
        args = tuple(map(jnp.asarray, (sb, ss, db, ds)))
        got_k, got_v = gc_compact(kp, vp, *args, interpret=True)
        ref_k, ref_v = gc_compact_ref(kp, vp, *args)
        np.testing.assert_array_equal(np.asarray(got_k), np.asarray(ref_k))
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(ref_v))


class TestCompactSlots:
    """Metadata-pool variant backing the simulator's bulk-GC drain: the
    pure-jnp fallback the simulator runs off-TPU must match the
    interpret-mode Pallas kernel move-for-move."""

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=1000))
    def test_kernel_matches_ref(self, seed):
        from repro.kernels.gc_compact.kernel import compact_slots
        from repro.kernels.gc_compact.ref import (
            compact_slots_dense,
            compact_slots_ref,
        )

        rng = np.random.default_rng(seed)
        k, b = 24, 8
        m = int(rng.integers(1, b + 1))
        slot_lba = rng.integers(-1, 200, (k, b)).astype(np.int32)
        valid = rng.random((k, b)) < 0.5
        # a GC-shaped move list: one victim block's slots → distinct dsts
        victim = int(rng.integers(0, k))
        dst_flat = rng.choice((k - 1) * b, m, replace=False)
        db = (dst_flat // b).astype(np.int32)
        db = np.where(db >= victim, db + 1, db).astype(np.int32)  # dst ≠ src
        ds = (dst_flat % b).astype(np.int32)
        sb = np.full(m, victim, np.int32)
        ss = rng.choice(b, m, replace=False).astype(np.int32)
        sb[rng.random(m) < 0.3] = -1  # no-op rows
        args = tuple(map(jnp.asarray, (sb, ss, db, ds)))
        got_l, got_v = compact_slots(
            jnp.asarray(slot_lba), jnp.asarray(valid), *args, interpret=True
        )
        ref_l, ref_v = compact_slots_ref(
            jnp.asarray(slot_lba), jnp.asarray(valid), *args
        )
        np.testing.assert_array_equal(np.asarray(got_l), np.asarray(ref_l))
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(ref_v))
        assert got_v.dtype == valid.dtype
        # the scatter-free CPU lowering the simulator actually runs
        den_l, den_v = compact_slots_dense(
            jnp.asarray(slot_lba), jnp.asarray(valid), *args
        )
        np.testing.assert_array_equal(np.asarray(den_l), np.asarray(ref_l))
        np.testing.assert_array_equal(np.asarray(den_v), np.asarray(ref_v))


class TestWritePath:
    """Fused fast-path write (invalidate + append + map repoint) backing the
    simulator's split step: the flattened off-TPU lowering must match both
    the 2-D reference and the interpret-mode Pallas kernel update-for-update."""

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=1000))
    def test_kernel_matches_ref(self, seed):
        from repro.kernels.write_path.kernel import apply_write
        from repro.kernels.write_path.ref import (
            apply_write_flat,
            apply_write_ref,
        )

        rng = np.random.default_rng(seed)
        k, b, lba_pages = 24, 8, 128
        slot_lba = rng.integers(-1, lba_pages, (k, b)).astype(np.int32)
        valid = rng.random((k, b)) < 0.5
        page_map = rng.integers(-1, k * b, lba_pages).astype(np.int32)
        lba = int(rng.integers(0, lba_pages))
        old_pm = int(page_map[lba])
        dst_blk = int(rng.integers(0, k))
        # a write-shaped destination: never the page's own old slot
        dst_slot = int(rng.integers(0, b))
        while dst_blk * b + dst_slot == old_pm:
            dst_slot = (dst_slot + 1) % b
        args = (
            jnp.asarray(page_map), jnp.asarray(slot_lba), jnp.asarray(valid),
            jnp.asarray(lba), jnp.asarray(old_pm),
            jnp.asarray(dst_blk), jnp.asarray(dst_slot),
        )
        ref_pm, ref_l, ref_v = apply_write_ref(*args)
        flat_pm, flat_l, flat_v = apply_write_flat(*args)
        ker_pm, ker_l, ker_v = apply_write(*args, interpret=True)
        for got, ref, name in (
            (flat_pm, ref_pm, "flat page_map"), (flat_l, ref_l, "flat slot_lba"),
            (flat_v, ref_v, "flat valid"),
            (ker_pm, ref_pm, "kernel page_map"), (ker_l, ref_l, "kernel slot_lba"),
            (ker_v, ref_v, "kernel valid"),
        ):
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(ref), err_msg=name
            )
        assert flat_v.dtype == valid.dtype and ker_v.dtype == valid.dtype
        # the new mapping is installed and the old slot is dead
        assert int(flat_pm[lba]) == dst_blk * b + dst_slot
        assert bool(flat_v[dst_blk, dst_slot])
        if old_pm >= 0:
            assert not bool(flat_v[old_pm // b, old_pm % b])

    def test_unmapped_page_touches_nothing_old(self):
        from repro.kernels.write_path.ref import (
            apply_write_flat,
            apply_write_ref,
        )

        k, b, lba_pages = 8, 4, 24
        page_map = jnp.full(lba_pages, -1, jnp.int32)
        slot_lba = jnp.full((k, b), -1, jnp.int32)
        valid = jnp.zeros((k, b), bool)
        for fn in (apply_write_ref, apply_write_flat):
            pm, sl, va = fn(
                page_map, slot_lba, valid,
                jnp.asarray(5), jnp.asarray(-1),
                jnp.asarray(2), jnp.asarray(0),
            )
            assert int(pm[5]) == 2 * b + 0
            assert int(va.sum()) == 1 and bool(va[2, 0])
            assert int(sl[2, 0]) == 5

    def test_disabled_kernel_write_is_noop(self):
        from repro.kernels.write_path.kernel import apply_write

        rng = np.random.default_rng(0)
        k, b, lba_pages = 8, 4, 24
        page_map = jnp.asarray(rng.integers(-1, k * b, lba_pages), jnp.int32)
        slot_lba = jnp.asarray(rng.integers(-1, lba_pages, (k, b)), jnp.int32)
        valid = jnp.asarray(rng.random((k, b)) < 0.5)
        pm, sl, va = apply_write(
            page_map, slot_lba, valid,
            jnp.asarray(3), jnp.asarray(5), jnp.asarray(1), jnp.asarray(2),
            enabled=jnp.asarray(False), interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(pm), np.asarray(page_map))
        np.testing.assert_array_equal(np.asarray(sl), np.asarray(slot_lba))
        np.testing.assert_array_equal(np.asarray(va), np.asarray(valid))


class TestTrimPath:
    """Fused fast-path TRIM (invalidate + unmap) — the discard peer of
    apply_write: flat lowering and interpret-mode kernel vs the 2-D ref."""

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=1000))
    def test_kernel_matches_ref(self, seed):
        from repro.kernels.write_path.kernel import apply_trim
        from repro.kernels.write_path.ref import (
            apply_trim_flat,
            apply_trim_ref,
        )

        rng = np.random.default_rng(seed)
        k, b, lba_pages = 24, 8, 128
        valid = rng.random((k, b)) < 0.5
        page_map = rng.integers(-1, k * b, lba_pages).astype(np.int32)
        lba = int(rng.integers(0, lba_pages))
        if rng.random() < 0.3:
            page_map[lba] = -1  # re-trim of an unmapped page
        old_pm = int(page_map[lba])
        args = (
            jnp.asarray(page_map), jnp.asarray(valid),
            jnp.asarray(lba), jnp.asarray(old_pm),
        )
        ref_pm, ref_v = apply_trim_ref(*args)
        flat_pm, flat_v = apply_trim_flat(*args)
        ker_pm, ker_v = apply_trim(*args, interpret=True)
        for got, ref, name in (
            (flat_pm, ref_pm, "flat page_map"), (flat_v, ref_v, "flat valid"),
            (ker_pm, ref_pm, "kernel page_map"), (ker_v, ref_v, "kernel valid"),
        ):
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(ref), err_msg=name
            )
        assert flat_v.dtype == valid.dtype and ker_v.dtype == valid.dtype
        # the page is unmapped and its old slot is dead
        assert int(flat_pm[lba]) == -1
        if old_pm >= 0:
            assert not bool(flat_v[old_pm // b, old_pm % b])

    def test_retrim_is_noop_on_valid(self):
        from repro.kernels.write_path.ref import (
            apply_trim_flat,
            apply_trim_ref,
        )

        k, b, lba_pages = 8, 4, 24
        page_map = jnp.full(lba_pages, -1, jnp.int32)
        valid = jnp.ones((k, b), bool)
        for fn in (apply_trim_ref, apply_trim_flat):
            pm, va = fn(page_map, valid, jnp.asarray(5), jnp.asarray(-1))
            assert int(pm[5]) == -1
            np.testing.assert_array_equal(np.asarray(va), np.ones((k, b), bool))

    def test_disabled_kernel_trim_is_noop(self):
        from repro.kernels.write_path.kernel import apply_trim

        rng = np.random.default_rng(0)
        k, b, lba_pages = 8, 4, 24
        page_map = jnp.asarray(rng.integers(0, k * b, lba_pages), jnp.int32)
        valid = jnp.asarray(rng.random((k, b)) < 0.5)
        pm, va = apply_trim(
            page_map, valid, jnp.asarray(3), jnp.asarray(5),
            enabled=jnp.asarray(False), interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(pm), np.asarray(page_map))
        np.testing.assert_array_equal(np.asarray(va), np.asarray(valid))
